"""Typed AST for the tAPP language (Fig. 4 of the paper).

Grammar (paper, Fig. 4)::

    app        ::= tag*
    tag        ::= policy_tag : block+  strategy?  followup?
    block      ::= controller?  workers  strategy?  constraint*
    controller ::= controller: label  (topology_tolerance: all|same|none)?
    workers    ::= workers: (wrk: label  constraint*)+
                 | workers: (set: label?  strategy?  constraint*)+
    strategy   ::= strategy: random | platform | best_first | warm-first
    constraint ::= invalidate | affinity | anti-affinity
    invalidate ::= invalidate: capacity_used n% | max_concurrent_invocations n | overload
    affinity   ::= affinity: fn (, fn)*            -- all must be running there
    anti-affinity ::= anti-affinity: fn (, fn)*    -- none may be running there
    followup   ::= followup: default | fail

The ``affinity``/``anti-affinity`` clauses are the constraint-layer-v2
extension (the authors' follow-up, arXiv:2407.14572): they constrain *what
else is running* on a worker, evaluated against the live per-worker
running-function multiset. At most one of each clause per level; item-level
clauses override block-level ones (same resolution rule as ``invalidate``).

The special ``default`` tag is the policy for untagged functions and the target of
``followup: default``; its own followup is always ``fail`` (paper §3.3).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence, Tuple, Union

DEFAULT_TAG = "default"


class Strategy(enum.Enum):
    """Item-selection strategy at tag, block, or worker-set level.

    ``WARM_FIRST`` (the warm-pool extension, ROADMAP item 1) orders
    candidates that hold an IDLE warm instance of the invoked function
    ahead of cold ones — a stable partition of the canonical best-first
    order, consuming zero RNG draws. With no lifecycle armed every
    worker is cold, so it degenerates to ``BEST_FIRST`` exactly.
    Valid at block and set-item level only (a tag-level warm-first is a
    validation error: tag strategies order *blocks*, which have no
    single warmth).
    """

    RANDOM = "random"
    PLATFORM = "platform"
    BEST_FIRST = "best_first"
    WARM_FIRST = "warm_first"

    @classmethod
    def parse(cls, text: str) -> "Strategy":
        try:
            return cls(text.strip())
        except ValueError:
            raise ValueError(
                f"unknown strategy {text!r}; expected one of "
                f"{[s.value for s in cls]}"
            ) from None


class TopologyTolerance(enum.Enum):
    """Failure tolerance of a ``controller`` clause (paper §3.3)."""

    ALL = "all"    # any alternative controller, any zone of workers (default)
    SAME = "same"  # alternative controller OK, workers must stay in the zone
    NONE = "none"  # no forwarding at all

    @classmethod
    def parse(cls, text: str) -> "TopologyTolerance":
        try:
            return cls(text.strip())
        except ValueError:
            raise ValueError(
                f"unknown topology_tolerance {text!r}; expected one of "
                f"{[t.value for t in cls]}"
            ) from None


class FollowupKind(enum.Enum):
    FAIL = "fail"
    DEFAULT = "default"

    @classmethod
    def parse(cls, text: str) -> "FollowupKind":
        try:
            return cls(text.strip())
        except ValueError:
            raise ValueError(
                f"unknown followup {text!r}; expected one of "
                f"{[f.value for f in cls]}"
            ) from None


class OnOverload(enum.Enum):
    """Tag-level brownout escape hatch (``on-overload:``, PR 9).

    Under sustained saturation (the platform's brownout signal), the tag
    either re-routes through a pre-compiled degraded plan —
    ``relax-affinity`` drops affinity/anti-affinity clauses,
    ``any-zone`` additionally widens designated controllers'
    ``topology_tolerance`` to ``all`` — or is shed immediately
    (``reject``) instead of queueing. Without the clause the tag is
    untouched by brownouts.
    """

    RELAX_AFFINITY = "relax-affinity"
    ANY_ZONE = "any-zone"
    REJECT = "reject"

    @classmethod
    def parse(cls, text: str) -> "OnOverload":
        try:
            return cls(text.strip())
        except ValueError:
            raise ValueError(
                f"unknown on-overload {text!r}; expected one of "
                f"{[o.value for o in cls]}"
            ) from None


# ---------------------------------------------------------------------------
# Invalidate conditions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Overload:
    """Worker lacks computational resources (platform health signal)."""

    def describe(self) -> str:
        return "overload"


@dataclasses.dataclass(frozen=True)
class CapacityUsed:
    """Worker reached a threshold percentage of capacity (CPU/HBM load)."""

    percent: float

    def __post_init__(self) -> None:
        if not (0.0 < self.percent <= 100.0):
            raise ValueError(
                f"capacity_used must be in (0, 100]; got {self.percent}"
            )

    def describe(self) -> str:
        pct = self.percent
        return f"capacity_used {int(pct) if pct == int(pct) else pct}%"


@dataclasses.dataclass(frozen=True)
class MaxConcurrentInvocations:
    """Worker reached a threshold of buffered concurrent invocations."""

    limit: int

    def __post_init__(self) -> None:
        if self.limit < 1:
            raise ValueError(
                f"max_concurrent_invocations must be >= 1; got {self.limit}"
            )

    def describe(self) -> str:
        return f"max_concurrent_invocations {self.limit}"


Invalidate = Union[Overload, CapacityUsed, MaxConcurrentInvocations]


# ---------------------------------------------------------------------------
# Affinity constraints (constraint layer v2; arXiv:2407.14572 semantics)
# ---------------------------------------------------------------------------


def _check_function_list(kind: str, functions: Tuple[str, ...]) -> None:
    if not functions:
        raise ValueError(f"{kind} requires at least one function name")
    for fn in functions:
        if not isinstance(fn, str) or not fn.strip():
            raise ValueError(f"{kind} function names must be non-empty strings")
    if len(set(functions)) != len(functions):
        raise ValueError(f"duplicate function in {kind} list: {functions}")


@dataclasses.dataclass(frozen=True)
class Affinity:
    """``affinity: <fn, ...>`` — co-location requirement.

    A worker is valid only if **every** listed function currently has at
    least one running (admitted) instance on it. Affinity gates on the live
    per-worker multiset, so a function listed here that is running nowhere
    makes the clause unsatisfiable — scripts should pair it with a fallback
    block or ``followup`` for bootstrap.
    """

    functions: Tuple[str, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "functions", tuple(self.functions))
        _check_function_list("affinity", self.functions)

    def describe(self) -> str:
        return "affinity " + ", ".join(self.functions)


@dataclasses.dataclass(frozen=True)
class AntiAffinity:
    """``anti-affinity: <fn, ...>`` — interference avoidance.

    A worker is invalid if **any** listed function currently has a running
    (admitted) instance on it. Listing a function's own name yields spread
    semantics: no two instances co-locate while alternatives exist.
    """

    functions: Tuple[str, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "functions", tuple(self.functions))
        _check_function_list("anti-affinity", self.functions)

    def describe(self) -> str:
        return "anti-affinity " + ", ".join(self.functions)


def affinity_from_value(kind: str, value) -> Tuple[str, ...]:
    """Parse an affinity function list from YAML: list form or comma string."""
    if isinstance(value, str):
        names = [part.strip() for part in value.split(",")]
    elif isinstance(value, (list, tuple)):
        names = [str(part).strip() for part in value]
    else:
        raise ValueError(
            f"{kind} expects a function list (e.g. '[fnA, fnB]' or "
            f"'fnA, fnB'); got {type(value).__name__}"
        )
    if any(not n for n in names):
        raise ValueError(f"{kind} contains an empty function name")
    return tuple(names)


# ---------------------------------------------------------------------------
# Worker items
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WorkerRef:
    """``wrk: label`` — one specific worker label (a singleton logical topology)."""

    label: str
    invalidate: Optional[Invalidate] = None
    affinity: Optional[Affinity] = None
    anti_affinity: Optional[AntiAffinity] = None


@dataclasses.dataclass(frozen=True)
class WorkerSet:
    """``set: label`` — a dynamically-populated set of workers.

    ``label is None`` (blank set) selects *all* workers visible to the
    controller. Sets may carry their own inner selection strategy and
    constraint clauses (paper §3.3; affinity extension).
    """

    label: Optional[str] = None
    strategy: Optional[Strategy] = None
    invalidate: Optional[Invalidate] = None
    affinity: Optional[Affinity] = None
    anti_affinity: Optional[AntiAffinity] = None


WorkerItem = Union[WorkerRef, WorkerSet]


# ---------------------------------------------------------------------------
# Blocks / tags / scripts
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ControllerClause:
    label: str
    topology_tolerance: TopologyTolerance = TopologyTolerance.ALL


@dataclasses.dataclass(frozen=True)
class Block:
    """One workers-block of a policy tag."""

    workers: Tuple[WorkerItem, ...]
    controller: Optional[ControllerClause] = None
    strategy: Optional[Strategy] = None
    invalidate: Optional[Invalidate] = None
    affinity: Optional[Affinity] = None
    anti_affinity: Optional[AntiAffinity] = None
    # Load-shedding priority (PR 9): when an admission queue is full the
    # lowest-priority entrant is shed. A tag's priority is the max over
    # its blocks; unset means 0 (shed first).
    priority: Optional[int] = None

    def __post_init__(self) -> None:
        if not self.workers:
            raise ValueError("a block must list at least one workers item")
        kinds = {type(w) for w in self.workers}
        if kinds == {WorkerRef, WorkerSet}:
            # The grammar separates wrk-lists from set-lists; mixing is invalid.
            raise ValueError("a workers list cannot mix 'wrk' and 'set' items")
        if self.priority is not None and (
            not isinstance(self.priority, int) or self.priority < 0
        ):
            raise ValueError(
                f"priority must be a non-negative integer; got "
                f"{self.priority!r}"
            )

    @property
    def uses_sets(self) -> bool:
        return bool(self.workers) and isinstance(self.workers[0], WorkerSet)


@dataclasses.dataclass(frozen=True)
class TagPolicy:
    """The full policy attached to one policy tag."""

    tag: str
    blocks: Tuple[Block, ...]
    strategy: Optional[Strategy] = None  # block-selection strategy
    followup: Optional[FollowupKind] = None
    # Brownout escape hatch (PR 9): what the platform may do with this
    # tag's requests under sustained saturation. None means never degrade.
    on_overload: Optional[OnOverload] = None

    def __post_init__(self) -> None:
        if not self.blocks:
            raise ValueError(f"tag {self.tag!r} must define at least one block")

    @property
    def effective_strategy(self) -> Strategy:
        # best_first is the default block-selection policy (paper §3.3).
        return self.strategy or Strategy.BEST_FIRST

    @property
    def effective_followup(self) -> FollowupKind:
        if self.tag == DEFAULT_TAG:
            # "the followup value of the default tag is always set to fail"
            return FollowupKind.FAIL
        return self.followup or FollowupKind.DEFAULT


@dataclasses.dataclass(frozen=True)
class TappScript:
    """A parsed tAPP script: an ordered collection of tag policies."""

    tags: Tuple[TagPolicy, ...]
    source: Optional[str] = None  # original YAML text, for provenance
    version: int = 0              # bumped by the watcher on live reload

    def __post_init__(self) -> None:
        seen = set()
        for t in self.tags:
            if t.tag in seen:
                raise ValueError(f"duplicate policy tag {t.tag!r}")
            seen.add(t.tag)

    def get(self, tag: str) -> Optional[TagPolicy]:
        for t in self.tags:
            if t.tag == tag:
                return t
        return None

    @property
    def default(self) -> Optional[TagPolicy]:
        return self.get(DEFAULT_TAG)

    def tag_names(self) -> Sequence[str]:
        return [t.tag for t in self.tags]


def invalidate_from_text(text: str) -> Invalidate:
    """Parse an invalidate condition from its textual form.

    Accepted forms: ``overload``, ``capacity_used 50%``,
    ``max_concurrent_invocations 100``.
    """
    text = str(text).strip()
    if text == "overload":
        return Overload()
    if text.startswith("capacity_used"):
        rest = text[len("capacity_used"):].strip()
        if rest.endswith("%"):
            rest = rest[:-1].strip()
        if not rest:
            raise ValueError("capacity_used requires a percentage, e.g. 'capacity_used 50%'")
        try:
            return CapacityUsed(float(rest))
        except ValueError as e:
            raise ValueError(f"bad capacity_used value {rest!r}") from e
    if text.startswith("max_concurrent_invocations"):
        rest = text[len("max_concurrent_invocations"):].strip()
        if not rest:
            raise ValueError(
                "max_concurrent_invocations requires a count, e.g. "
                "'max_concurrent_invocations 100'"
            )
        try:
            return MaxConcurrentInvocations(int(rest))
        except ValueError as e:
            raise ValueError(f"bad max_concurrent_invocations value {rest!r}") from e
    raise ValueError(
        f"unknown invalidate condition {text!r}; expected 'overload', "
        f"'capacity_used n%', or 'max_concurrent_invocations n'"
    )
