"""AST → YAML serialization (round-trips through :func:`parse_tapp`).

Used by the watcher to persist the canonical policy store and by tooling
that synthesizes tAPP scripts programmatically (e.g. the topology-aware
deployment generator in ``launch/serve.py``).
"""
from __future__ import annotations

from typing import Any, Dict, List

import yaml

from repro.core.tapp.ast import (
    Block,
    Invalidate,
    TagPolicy,
    TappScript,
    TopologyTolerance,
    WorkerRef,
    WorkerSet,
)


def _constraints_to_obj(item, obj: Dict[str, Any]) -> None:
    """Emit the optional constraint clauses of a block or worker item."""
    if item.invalidate is not None:
        obj["invalidate"] = _inv_to_text(item.invalidate)
    if item.affinity is not None:
        obj["affinity"] = list(item.affinity.functions)
    if item.anti_affinity is not None:
        obj["anti-affinity"] = list(item.anti_affinity.functions)


def script_to_obj(script: TappScript) -> List[Dict[str, Any]]:
    return [_tag_to_obj(tag) for tag in script.tags]


def script_to_yaml(script: TappScript) -> str:
    return yaml.safe_dump(script_to_obj(script), sort_keys=False)


def _tag_to_obj(tag: TagPolicy) -> Dict[str, Any]:
    body: List[Dict[str, Any]] = [_block_to_obj(b) for b in tag.blocks]
    if tag.strategy is not None:
        body.append({"strategy": tag.strategy.value})
    if tag.followup is not None:
        body.append({"followup": tag.followup.value})
    if tag.on_overload is not None:
        body.append({"on-overload": tag.on_overload.value})
    return {tag.tag: body}


def _block_to_obj(block: Block) -> Dict[str, Any]:
    obj: Dict[str, Any] = {}
    if block.controller is not None:
        obj["controller"] = block.controller.label
        if block.controller.topology_tolerance is not TopologyTolerance.ALL:
            obj["topology_tolerance"] = block.controller.topology_tolerance.value
    workers: List[Dict[str, Any]] = []
    for item in block.workers:
        if isinstance(item, WorkerRef):
            w: Dict[str, Any] = {"wrk": item.label}
            _constraints_to_obj(item, w)
            workers.append(w)
        elif isinstance(item, WorkerSet):
            w = {"set": item.label}
            if item.strategy is not None:
                w["strategy"] = item.strategy.value
            _constraints_to_obj(item, w)
            workers.append(w)
    obj["workers"] = workers
    if block.strategy is not None:
        obj["strategy"] = block.strategy.value
    if block.priority is not None:
        obj["priority"] = block.priority
    _constraints_to_obj(block, obj)
    return obj


def _inv_to_text(inv: Invalidate) -> str:
    return inv.describe()
