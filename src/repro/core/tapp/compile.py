"""Script compilation: lower a parsed :class:`TappScript` to execution plans.

The interpreter in :mod:`repro.core.scheduler.engine` re-derives, on every
scheduling decision, facts that are pure functions of the script text:
effective strategies/followups, the wrk-vs-set shape of each block, the
resolved constraint set of each worker item (item ▸ block ▸ platform
default — invalidate condition plus affinity / anti-affinity clauses),
and the ``topology_tolerance: same`` sticky-zone scan performed on
followup. Compilation hoists all of that to script-load time, so the
per-decision cost is amortized-O(candidates tried):

* each tag becomes a :class:`CompiledTag` with its effective strategy,
  effective followup, and the ordered sticky-zone label table;
* each block becomes a :class:`CompiledBlock` pre-split into either a
  wrk-list (:class:`CompiledWrk`) or a set-list (:class:`CompiledSet`),
  with the block-level strategy defaulted;
* each worker item carries its resolved
  :class:`~repro.core.scheduler.constraints.ConstraintSpec` AND a
  pre-bound ``invalid(worker) -> bool`` closure lowered by the constraint
  layer (:func:`~repro.core.scheduler.constraints.compile_spec`),
  eliminating per-candidate dispatch no matter how many constraint kinds
  the item stacks.

Compilation is semantics-preserving by construction: the compiled
evaluator (``TappEngine`` with ``compiled=True``) produces bit-identical
placements and traces to the interpreter under a fixed RNG seed — this is
property-tested in ``tests/test_scheduler_compile.py``.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, Optional, Tuple

if TYPE_CHECKING:  # the constraint layer lives scheduler-side; importing it
    # at module scope would close a cycle (scheduler.constraints needs
    # tapp.ast, whose package init loads this module). Lowering happens at
    # script-compile time, when everything is loaded — see _constraints().
    from repro.core.scheduler.constraints import ConstraintSpec, InvalidFn

from repro.core.tapp.ast import (
    DEFAULT_TAG,
    Block,
    ControllerClause,
    FollowupKind,
    Invalidate,
    OnOverload,
    Strategy,
    TagPolicy,
    TappScript,
    TopologyTolerance,
    WorkerRef,
    WorkerSet,
)

__all__ = [
    "CompiledBlock",
    "CompiledScript",
    "CompiledSet",
    "CompiledTag",
    "CompiledWrk",
    "compile_invalidate",
    "compile_script",
]


def _constraints():
    from repro.core.scheduler import constraints

    return constraints


def compile_invalidate(condition: Invalidate) -> "InvalidFn":
    """Pre-bind an invalidate condition (re-export of the constraint layer)."""
    return _constraints().compile_invalidate(condition)


@dataclasses.dataclass(frozen=True, eq=False)
class CompiledWrk:
    """A ``wrk: label`` item with its constraints resolved and pre-bound.

    ``invalid`` is the fused closure (reachability ∧ invalidate ∧
    affinity); ``static_invalid`` / ``dyn_invalid`` are its epoch-static
    vs. volatile halves (:func:`~repro.core.scheduler.constraints.split_spec`)
    consumed by the per-epoch candidate indexes. Identity-hashed
    (``eq=False``): compiled items key the per-view index caches, so
    hashing must be O(1) on the decision hot path.
    """

    label: str
    spec: ConstraintSpec
    invalid: InvalidFn
    static_invalid: InvalidFn
    dyn_invalid: InvalidFn

    @property
    def condition(self) -> Invalidate:
        """The resolved invalidate condition (legacy accessor)."""
        return self.spec.invalidate


@dataclasses.dataclass(frozen=True, eq=False)
class CompiledSet:
    """A ``set: label`` item with strategy + constraints pre-resolved."""

    label: Optional[str]
    strategy: Strategy  # inner member-selection strategy (platform default)
    spec: ConstraintSpec
    invalid: InvalidFn
    static_invalid: InvalidFn
    dyn_invalid: InvalidFn

    @property
    def condition(self) -> Invalidate:
        """The resolved invalidate condition (legacy accessor)."""
        return self.spec.invalidate


@dataclasses.dataclass(frozen=True, eq=False)
class CompiledBlock:
    """One workers-block, pre-split by shape with strategy defaulted.

    Identity-hashed (``eq=False``): the epoch-cached view entries key
    their :class:`~repro.core.scheduler.topology.BlockIndex` caches by
    the block object itself.
    """

    index: int  # position in the tag's source order (trace identity)
    controller: Optional[ControllerClause]
    strategy: Strategy  # effective block-level item strategy
    uses_sets: bool
    wrks: Tuple[CompiledWrk, ...] = ()
    sets: Tuple[CompiledSet, ...] = ()
    priority: int = 0  # load-shedding priority (PR 9); unset lowers to 0


@dataclasses.dataclass(frozen=True)
class CompiledTag:
    """Per-tag execution plan."""

    tag: str
    strategy: Strategy          # effective block-selection strategy
    followup: FollowupKind      # effective followup (default tag → fail)
    blocks: Tuple[CompiledBlock, ...]
    # Base ordering fed to the block-selection strategy: (index, block)
    # pairs in source order, mirroring the interpreter's enumerate().
    enumerated: Tuple[Tuple[int, CompiledBlock], ...]
    # topology_tolerance:same sticky-zone table (paper §3.4): controller
    # labels, in block source order, whose zone pins a followup-to-default
    # evaluation. The first label present in the live cluster wins.
    sticky_same_labels: Tuple[str, ...]
    # Overload layer (PR 9): tag-wide shedding priority (max over block
    # priorities) and the brownout escape hatch, if declared.
    priority: int = 0
    on_overload: Optional[OnOverload] = None


@dataclasses.dataclass(frozen=True)
class CompiledScript:
    """A fully lowered tAPP script, keyed for O(1) tag dispatch."""

    source: TappScript
    tags: Dict[str, CompiledTag]
    default: Optional[CompiledTag]


def _compile_block(index: int, block: Block) -> CompiledBlock:
    layer = _constraints()
    strategy = block.strategy or Strategy.BEST_FIRST
    if block.uses_sets:
        sets = tuple(
            CompiledSet(
                label=item.label,
                strategy=item.strategy or Strategy.PLATFORM,
                spec=(spec := layer.resolve_constraints(item, block)),
                invalid=layer.compile_spec(spec),
                static_invalid=(halves := layer.split_spec(spec))[0],
                dyn_invalid=halves[1],
            )
            for item in block.workers
            if isinstance(item, WorkerSet)
        )
        return CompiledBlock(
            index=index,
            controller=block.controller,
            strategy=strategy,
            uses_sets=True,
            sets=sets,
            priority=block.priority or 0,
        )
    wrks = tuple(
        CompiledWrk(
            label=item.label,
            spec=(spec := layer.resolve_constraints(item, block)),
            invalid=layer.compile_spec(spec),
            static_invalid=(halves := layer.split_spec(spec))[0],
            dyn_invalid=halves[1],
        )
        for item in block.workers
        if isinstance(item, WorkerRef)
    )
    return CompiledBlock(
        index=index,
        controller=block.controller,
        strategy=strategy,
        uses_sets=False,
        wrks=wrks,
        priority=block.priority or 0,
    )


def _compile_tag(policy: TagPolicy) -> CompiledTag:
    blocks = tuple(
        _compile_block(i, b) for i, b in enumerate(policy.blocks)
    )
    sticky = tuple(
        b.controller.label
        for b in policy.blocks
        if b.controller is not None
        and b.controller.topology_tolerance is TopologyTolerance.SAME
    )
    return CompiledTag(
        tag=policy.tag,
        strategy=policy.effective_strategy,
        followup=policy.effective_followup,
        blocks=blocks,
        enumerated=tuple(enumerate(blocks)),
        sticky_same_labels=sticky,
        priority=max((b.priority for b in blocks), default=0),
        on_overload=policy.on_overload,
    )


def compile_script(script: TappScript) -> CompiledScript:
    """Lower a parsed script into per-tag execution plans."""
    tags = {t.tag: _compile_tag(t) for t in script.tags}
    return CompiledScript(
        source=script, tags=tags, default=tags.get(DEFAULT_TAG)
    )
