"""YAML → :class:`TappScript` parser.

The concrete syntax follows the paper's examples (Figs. 5, 6, 8): a tAPP
script is a YAML list of single-key mappings ``{policy_tag: [...blocks...]}``
where the block list may be followed by tag-level ``strategy`` / ``followup``
entries (YAML's indentation in the paper attaches them to the tag).

Because the paper writes tag options *inside* the same list as blocks, e.g.::

    - couchdb_query:
      - workers: ...
        strategy: random
      - workers: ...
      followup: fail          # <- tag level

real-world YAML parsers read that trailing scalar differently; we accept both
the list-item form (``- followup: fail``) and a mapping form::

    - couchdb_query:
        blocks: [...]
        strategy: best_first
        followup: fail

as well as the paper-faithful inline form where tag-level keys appear as the
final entries of the block list.
"""
from __future__ import annotations

from typing import Any, List, Mapping, Optional, Tuple

import yaml

from repro.core.tapp.ast import (
    Affinity,
    AntiAffinity,
    Block,
    ControllerClause,
    FollowupKind,
    Invalidate,
    OnOverload,
    Strategy,
    TagPolicy,
    TappScript,
    TopologyTolerance,
    WorkerItem,
    WorkerRef,
    WorkerSet,
    affinity_from_value,
    invalidate_from_text,
)


class TappParseError(ValueError):
    """Raised on malformed tAPP scripts, with a path for debuggability."""

    def __init__(self, message: str, path: str = "") -> None:
        self.path = path
        super().__init__(f"{path}: {message}" if path else message)


_TAG_LEVEL_KEYS = {"strategy", "followup", "on-overload"}
_CONSTRAINT_KEYS = {"invalidate", "affinity", "anti-affinity"}
_BLOCK_KEYS = (
    {"controller", "topology_tolerance", "workers", "strategy", "priority"}
    | _CONSTRAINT_KEYS
)


def parse_tapp(text: str) -> TappScript:
    """Parse a tAPP YAML document into a validated AST."""
    try:
        doc = yaml.safe_load(text)
    except yaml.YAMLError as e:
        raise TappParseError(f"invalid YAML: {e}") from e
    if doc is None:
        return TappScript(tags=(), source=text)
    if not isinstance(doc, list):
        raise TappParseError(
            f"a tAPP script must be a YAML list of tag policies; got {type(doc).__name__}"
        )
    tags: List[TagPolicy] = []
    for i, entry in enumerate(doc):
        path = f"$[{i}]"
        if not isinstance(entry, Mapping) or not entry:
            raise TappParseError(
                "each top-level entry must be a mapping "
                "'{policy_tag: blocks}'",
                path,
            )
        # YAML parses the paper's trailing tag options (e.g. a dedented
        # 'followup: fail' after the block list) as sibling keys of the
        # tag key; accept them as tag-level options.
        tag_keys = [k for k in entry if k not in _TAG_LEVEL_KEYS]
        if len(tag_keys) != 1:
            raise TappParseError(
                "each top-level entry must contain exactly one policy tag "
                f"(plus optional {sorted(_TAG_LEVEL_KEYS)}); got keys "
                f"{sorted(map(str, entry.keys()))}",
                path,
            )
        tag_name = tag_keys[0]
        if not isinstance(tag_name, str) or not tag_name:
            raise TappParseError("policy tag must be a non-empty string", path)
        options = {k: v for k, v in entry.items() if k in _TAG_LEVEL_KEYS}
        tags.append(_parse_tag(str(tag_name), entry[tag_name], path, options))
    try:
        return TappScript(tags=tuple(tags), source=text)
    except ValueError as e:
        raise TappParseError(str(e)) from e


def parse_tapp_file(path: str) -> TappScript:
    with open(path, "r", encoding="utf-8") as fh:
        return parse_tapp(fh.read())


# ---------------------------------------------------------------------------


def _parse_tag(
    tag: str,
    body: Any,
    path: str,
    options: Optional[Mapping[str, Any]] = None,
) -> TagPolicy:
    path = f"{path}.{tag}"
    strategy: Optional[Strategy] = None
    followup: Optional[FollowupKind] = None
    on_overload: Optional[OnOverload] = None
    block_items: List[Any] = []
    if options:
        if "strategy" in options:
            strategy = _parse_strategy(options["strategy"], path)
        if "followup" in options:
            followup = _parse_followup(options["followup"], path)
        if "on-overload" in options:
            on_overload = _parse_on_overload(options["on-overload"], path)

    if isinstance(body, Mapping):
        # mapping form: {blocks: [...], strategy: ..., followup: ...}
        extra = set(body) - ({"blocks"} | _TAG_LEVEL_KEYS)
        if extra:
            raise TappParseError(f"unknown tag keys {sorted(extra)}", path)
        block_items = list(body.get("blocks") or [])
        if "strategy" in body:
            strategy = _parse_strategy(body["strategy"], path)
        if "followup" in body:
            followup = _parse_followup(body["followup"], path)
        if "on-overload" in body:
            on_overload = _parse_on_overload(body["on-overload"], path)
    elif isinstance(body, list):
        for j, item in enumerate(body):
            ipath = f"{path}[{j}]"
            if not isinstance(item, Mapping):
                raise TappParseError(
                    f"expected a mapping (block or tag option); got {type(item).__name__}",
                    ipath,
                )
            keys = set(item.keys())
            if keys <= _TAG_LEVEL_KEYS:
                # '- strategy: ...' / '- followup: ...' list items
                if "strategy" in item:
                    if strategy is not None:
                        raise TappParseError("duplicate tag-level strategy", ipath)
                    strategy = _parse_strategy(item["strategy"], ipath)
                if "followup" in item:
                    if followup is not None:
                        raise TappParseError("duplicate tag-level followup", ipath)
                    followup = _parse_followup(item["followup"], ipath)
                if "on-overload" in item:
                    if on_overload is not None:
                        raise TappParseError(
                            "duplicate tag-level on-overload", ipath
                        )
                    on_overload = _parse_on_overload(item["on-overload"], ipath)
            else:
                block_items.append(item)
    else:
        raise TappParseError(
            f"tag body must be a list of blocks; got {type(body).__name__}", path
        )

    if not block_items:
        raise TappParseError("tag must define at least one block", path)

    blocks = tuple(
        _parse_block(item, f"{path}[{j}]") for j, item in enumerate(block_items)
    )
    try:
        return TagPolicy(
            tag=tag,
            blocks=blocks,
            strategy=strategy,
            followup=followup,
            on_overload=on_overload,
        )
    except ValueError as e:
        raise TappParseError(str(e), path) from e


def _parse_block(item: Mapping[str, Any], path: str) -> Block:
    # The paper's YAML sometimes nests tag-level strategy/followup *after* the
    # workers key within the last block; here each block is its own mapping.
    extra = set(item) - _BLOCK_KEYS
    if extra:
        raise TappParseError(f"unknown block keys {sorted(extra)}", path)
    if "workers" not in item:
        raise TappParseError("block is missing the 'workers' key", path)

    controller: Optional[ControllerClause] = None
    if "controller" in item:
        label = item["controller"]
        if not isinstance(label, str) or not label:
            raise TappParseError("controller label must be a non-empty string", path)
        tolerance = TopologyTolerance.ALL
        if "topology_tolerance" in item:
            tolerance = _parse_tolerance(item["topology_tolerance"], path)
        controller = ControllerClause(label=label, topology_tolerance=tolerance)
    elif "topology_tolerance" in item:
        raise TappParseError(
            "topology_tolerance requires a controller clause", path
        )

    strategy = _parse_strategy(item["strategy"], path) if "strategy" in item else None
    invalidate = (
        _parse_invalidate(item["invalidate"], path) if "invalidate" in item else None
    )
    priority: Optional[int] = None
    if "priority" in item:
        raw = item["priority"]
        if isinstance(raw, bool) or not isinstance(raw, int) or raw < 0:
            raise TappParseError(
                f"priority must be a non-negative integer; got {raw!r}", path
            )
        priority = raw
    affinity, anti_affinity = _parse_affinities(item, path)
    workers = _parse_workers(item["workers"], f"{path}.workers")
    try:
        return Block(
            workers=workers,
            controller=controller,
            strategy=strategy,
            invalidate=invalidate,
            affinity=affinity,
            anti_affinity=anti_affinity,
            priority=priority,
        )
    except ValueError as e:
        raise TappParseError(str(e), path) from e


def _parse_workers(body: Any, path: str) -> Tuple[WorkerItem, ...]:
    if body is None:
        # 'workers:' with nothing below it — treat as the blank set (all workers).
        return (WorkerSet(label=None),)
    if not isinstance(body, list):
        raise TappParseError(
            f"workers must be a list of 'wrk:'/'set:' items; got {type(body).__name__}",
            path,
        )
    items: List[WorkerItem] = []
    for j, entry in enumerate(body):
        ipath = f"{path}[{j}]"
        if not isinstance(entry, Mapping):
            raise TappParseError(
                f"workers item must be a mapping; got {type(entry).__name__}", ipath
            )
        keys = set(entry.keys())
        if "wrk" in keys:
            extra = keys - ({"wrk"} | _CONSTRAINT_KEYS)
            if extra:
                raise TappParseError(f"unknown wrk keys {sorted(extra)}", ipath)
            label = entry["wrk"]
            if not isinstance(label, str) or not label:
                raise TappParseError("wrk label must be a non-empty string", ipath)
            inv = (
                _parse_invalidate(entry["invalidate"], ipath)
                if "invalidate" in entry
                else None
            )
            aff, anti = _parse_affinities(entry, ipath)
            items.append(
                WorkerRef(
                    label=label, invalidate=inv, affinity=aff, anti_affinity=anti
                )
            )
        elif "set" in keys:
            extra = keys - ({"set", "strategy"} | _CONSTRAINT_KEYS)
            if extra:
                raise TappParseError(f"unknown set keys {sorted(extra)}", ipath)
            label = entry["set"]
            if label is not None and (not isinstance(label, str) or not label):
                raise TappParseError(
                    "set label must be a non-empty string or blank (all workers)",
                    ipath,
                )
            strat = (
                _parse_strategy(entry["strategy"], ipath)
                if "strategy" in entry
                else None
            )
            inv = (
                _parse_invalidate(entry["invalidate"], ipath)
                if "invalidate" in entry
                else None
            )
            aff, anti = _parse_affinities(entry, ipath)
            items.append(
                WorkerSet(
                    label=label,
                    strategy=strat,
                    invalidate=inv,
                    affinity=aff,
                    anti_affinity=anti,
                )
            )
        else:
            raise TappParseError(
                f"workers item must have a 'wrk' or 'set' key; got {sorted(keys)}",
                ipath,
            )
    return tuple(items)


def _parse_strategy(value: Any, path: str) -> Strategy:
    # Accept the paper's 'best-first' spelling variant (Fig. 8) too.
    text = str(value).strip().replace("-", "_")
    try:
        return Strategy.parse(text)
    except ValueError as e:
        raise TappParseError(str(e), path) from e


def _parse_followup(value: Any, path: str) -> FollowupKind:
    try:
        return FollowupKind.parse(str(value))
    except ValueError as e:
        raise TappParseError(str(e), path) from e


def _parse_on_overload(value: Any, path: str) -> OnOverload:
    try:
        return OnOverload.parse(str(value))
    except ValueError as e:
        raise TappParseError(str(e), path) from e


def _parse_tolerance(value: Any, path: str) -> TopologyTolerance:
    try:
        return TopologyTolerance.parse(str(value))
    except ValueError as e:
        raise TappParseError(str(e), path) from e


def _parse_invalidate(value: Any, path: str) -> Invalidate:
    try:
        return invalidate_from_text(str(value))
    except ValueError as e:
        raise TappParseError(str(e), path) from e


def _parse_affinities(
    entry: Mapping[str, Any], path: str
) -> Tuple[Optional[Affinity], Optional[AntiAffinity]]:
    """Parse the optional affinity / anti-affinity clauses of one mapping."""
    affinity: Optional[Affinity] = None
    anti: Optional[AntiAffinity] = None
    if "affinity" in entry:
        try:
            affinity = Affinity(affinity_from_value("affinity", entry["affinity"]))
        except ValueError as e:
            raise TappParseError(str(e), path) from e
    if "anti-affinity" in entry:
        try:
            anti = AntiAffinity(
                affinity_from_value("anti-affinity", entry["anti-affinity"])
            )
        except ValueError as e:
            raise TappParseError(str(e), path) from e
    return affinity, anti
