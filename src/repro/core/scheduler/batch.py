"""Vectorized mask-plane batch routing (the ``route_batch`` kernel path).

:meth:`TappEngine.schedule_batch` historically looped ``schedule()`` —
per invocation it re-walked the compiled cascade, re-scanned platform
orders, and re-built a ``ScheduleDecision`` from scratch. This module
replaces that loop for the common case with three layers that together
make a batch decision a couple of dict hits:

* **Mask-plane kernel picks.** Batch items are grouped by the
  ``ItemIndex`` they route through (one index per compiled block × view
  entry × worker item — the "compiled block × strategy" grouping of a
  batch). For ``platform``-strategy picks, the group's distinct function
  hashes are stacked into one int32 ``[m, L]`` order plane, the index's
  availability bitmask is viewed as uint64 words, and
  :func:`repro.kernels.ops.select_first_available` resolves "first set
  bit in order" for every row at once. Planes are keyed by
  ``(index, avail)`` so they self-invalidate the moment an admission
  flips any candidate bit. ``backend="numpy"`` uses the reference
  kernel in :mod:`repro.kernels.ref`; ``backend="jax"`` lowers the
  identical computation through jit (``REPRO_BATCH_BACKEND`` overrides).

* **Zero-draw cascade solving.** The solver mirrors the compiled
  engine's evaluation (`_c_tag`/`_c_block`/`_c_pick`) exactly, but never
  touches the RNG: every point where the reference path *would* draw —
  ``random`` over two or more blocks, set items, or tier members —
  raises :class:`_NeedsScalar` and the item falls back to a plain
  ``engine.schedule()`` call. A ``random`` ordering over zero or one
  candidates consumes zero draws in every reference path, so such items
  stay vectorizable and the RNG stream is bit-identical either way.
  Round-robin cursor bumps are tracked virtually (the solver never
  mutates engine state), and the solved outcome is memoized by
  ``cursor mod lcm(site lengths)`` — sound because the evaluation path
  is a deterministic function of the cursor's residues at the
  controller-list sites it visits.

* **Intra-batch admission correction.** Outcome records are valid only
  under an unchanged ``(topology_epoch, load total, warm seq)`` token
  (the warm-event sequence is part of the token because a lifecycle
  janitor expiry changes warm-first outcomes *without* a load event). When an
  ``on_decision`` callback admits a placement mid-batch (the platform
  does, for every scheduled item), the token moves: cached outcomes and
  planes are dropped and the remaining items are solved freshly against
  the synced availability masks with scalar picks — capacity consumed by
  earlier items in the same batch is respected, and results stay
  bit-identical to a sequence of ``schedule()`` calls with interleaved
  admissions.

Placements, traces (the batch path only runs untraced), RNG streams,
cursor movement, and every ``ScheduleDecision`` field are bit-identical
to the sequential loop; ``tests/test_batch_vectorized.py`` property-tests
this under saturation, churn, epoch bumps, and mixed strategies.
"""
from __future__ import annotations

from math import lcm
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler.state import ClusterState, ControllerState
from repro.core.scheduler.strategy import coprime_order_cached
from repro.core.scheduler.topology import ItemIndex, cached_view_entry
from repro.core.tapp.ast import (
    DEFAULT_TAG,
    FollowupKind,
    Strategy,
    TopologyTolerance,
)

__all__ = ["BatchRouter"]

# Cache bounds: both caches are cleared wholesale at the cap (entries are
# cheap to rebuild and correctness never depends on retention).
_OUTCOME_CACHE_LIMIT = 4096
_PLANE_CACHE_LIMIT = 1024
# Residue records kept per (tag, zone, fhash) before the list is reset;
# also bounds the modulus a record may memoize under.
_RESIDUE_LIMIT = 128


class _NeedsScalar(Exception):
    """The cascade would consume RNG draws → route this item scalar."""


class _Ctx:
    """Mutable solve context: virtual cursor + modulus + zone restriction."""

    __slots__ = ("cur", "mod", "zr")

    def __init__(self, cursor: int) -> None:
        self.cur = cursor
        self.mod = 1
        self.zr: Optional[str] = None


class _Record:
    """One memoized cascade outcome, keyed by cursor residue.

    ``proto is None`` marks a cascade that aborted to the scalar path
    (it would draw RNG under this residue); otherwise ``proto`` is the
    pre-built ``ScheduleDecision.__dict__`` the replay copies (a fresh
    trace list is spliced in per decision), and ``delta`` is the cursor
    advance the cascade consumed.
    """

    __slots__ = ("modulus", "residue", "delta", "proto")

    def __init__(self, modulus: int, residue: int) -> None:
        self.modulus = modulus
        self.residue = residue
        self.delta = 0
        self.proto: Optional[dict] = None


class BatchRouter:
    """Vectorized batch evaluator bolted onto one :class:`TappEngine`.

    Owns the outcome and mask-plane caches; reads the engine's cursor,
    RNG (only through scalar fallbacks), distribution policy, and
    compiled plan. Not thread-safe, exactly like the engine it serves.
    """

    def __init__(self, engine, *, backend: str = "numpy") -> None:
        if backend not in ("numpy", "jax"):
            raise ValueError(
                f"unknown batch backend {backend!r}; expected 'numpy' or 'jax'"
            )
        self._engine = engine
        self._backend = backend
        self._select = None  # kernels.ops.select_first_available, lazy
        self._np = None
        self._decision_cls = None  # ScheduleDecision / outcomes, lazy
        self._scheduled_outcome = None
        self._failed_outcome = None
        self._plan = None
        self._token: Tuple[int, int, int] = (-1, -1, -1)
        self._churn = False
        # (tag, hash, proto) of the last zero-delta replay, for the
        # identical-run fast path in route_batch; None when the last
        # item solved scalar, failed statically, or moved the cursor.
        self._reuse: Optional[Tuple] = None
        # (id(ctag), entry_zone, fhash) → list of _Record (residue-keyed).
        self._outcomes: Dict[Tuple, List[_Record]] = {}
        # (ItemIndex.serial, avail int) → {fhash: pick or -1}. The
        # serial is process-unique and monotonic, so a collected index
        # whose id() gets re-used can never serve another index's plane.
        self._planes: Dict[Tuple, Dict[int, int]] = {}
        self._batch_hashes: Tuple[int, ...] = ()

    # -- public entry --------------------------------------------------------

    def route_batch(
        self,
        invocations: Sequence,
        script,
        plan,
        cluster: ClusterState,
        entry_zone: Optional[str],
        on_decision,
    ) -> List:
        if self._decision_cls is None:
            from repro.core.scheduler.engine import Outcome, ScheduleDecision

            self._decision_cls = ScheduleDecision
            self._scheduled_outcome = Outcome.SCHEDULED
            self._failed_outcome = Outcome.FAILED
        if plan is not self._plan:
            # New compiled plan: ctag identities are stale (ids may be
            # reused across plan objects), drop everything.
            self._outcomes.clear()
            self._planes.clear()
            self._plan = plan
        seen = {}
        for inv in invocations:
            seen.setdefault(inv.hash, None)
        self._batch_hashes = tuple(seen)
        self._churn = False
        self._sync_token(cluster)

        decisions = []
        append = decisions.append
        decide = self._decide
        cls = self._decision_cls
        engine = self._engine
        # Run-of-identical-items fast path: consecutive items with the
        # same (tag, hash) — the dominant batch shape — scan the cached
        # residue records directly, skipping tag dispatch, cache-key
        # construction, and the outcome-cache lookup per item.
        reuse_tag = reuse_hash = reuse_records = None
        epoch, load, warm = self._token
        for inv in invocations:
            if (
                cluster.topology_epoch != epoch
                or cluster._load_total != load
                or cluster._warm_total != warm
            ):
                # State moved mid-batch (on_decision admissions, epoch
                # bumps, warm-pool flips): drop memoized outcomes and
                # planes, re-solve the rest against the synced masks
                # with scalar picks.
                epoch = cluster.topology_epoch
                load = cluster._load_total
                warm = cluster._warm_total
                self._outcomes.clear()
                self._planes.clear()
                self._token = (epoch, load, warm)
                self._churn = True
                reuse_records = None
            decision = None
            if (
                reuse_records is not None
                and inv.hash == reuse_hash
                and inv.tag == reuse_tag
            ):
                cursor = engine._controller_cursor
                for rec in reuse_records:
                    if cursor % rec.modulus == rec.residue:
                        proto = rec.proto
                        if proto is None:
                            break  # scalar marker → full dispatch
                        if rec.delta:
                            engine._controller_cursor = cursor + rec.delta
                        fields = proto.copy()
                        fields["trace"] = []
                        decision = cls.__new__(cls)
                        decision.__dict__ = fields
                        break
            if decision is None:
                decision = decide(inv, script, plan, cluster, entry_zone)
                reuse = self._reuse
                if reuse is not None:
                    reuse_tag, reuse_hash, reuse_records = reuse
                else:
                    reuse_records = None
            if on_decision is not None:
                on_decision(inv, decision)
            append(decision)
        return decisions

    def _sync_token(self, cluster: ClusterState) -> None:
        token = (
            cluster.topology_epoch, cluster._load_total, cluster._warm_total
        )
        if token != self._token:
            self._outcomes.clear()
            self._planes.clear()
            self._token = token

    # -- per-item dispatch ---------------------------------------------------

    def _decide(self, inv, script, plan, cluster, entry_zone):
        self._reuse = None
        ctag = plan.tags.get(inv.tag or DEFAULT_TAG)
        if ctag is None:
            ctag = plan.default
            if ctag is None:
                return self._decision_cls(
                    outcome=self._failed_outcome, failed_by_policy=True
                )
        engine = self._engine
        cursor = engine._controller_cursor
        key = (id(ctag), entry_zone, inv.hash)
        records = self._outcomes.get(key)
        rec = None
        if records is not None:
            for cand in records:
                if cursor % cand.modulus == cand.residue:
                    rec = cand
                    break
        if rec is None:
            rec = self._solve(
                inv.hash, ctag, plan, cluster, entry_zone, cursor
            )
            if records is None:
                if len(self._outcomes) >= _OUTCOME_CACHE_LIMIT:
                    self._outcomes.clear()
                records = self._outcomes[key] = []
            elif len(records) >= _RESIDUE_LIMIT:
                del records[:]
            records.append(rec)
        self._reuse = (inv.tag, inv.hash, records)
        proto = rec.proto
        if proto is None:
            return engine.schedule(inv, script, cluster, entry_zone=entry_zone)
        if rec.delta:
            engine._controller_cursor = cursor + rec.delta
        # Replay: splat the memoized decision dict onto a bare instance
        # (the dataclass __init__ is ~half the per-item budget); the
        # trace list must be fresh per decision.
        cls = self._decision_cls
        decision = cls.__new__(cls)
        fields = proto.copy()
        fields["trace"] = []
        decision.__dict__ = fields
        return decision

    # -- the zero-draw cascade solver ---------------------------------------

    def _solve(
        self,
        fhash: int,
        ctag,
        plan,
        cluster: ClusterState,
        entry_zone: Optional[str],
        cursor: int,
    ) -> _Record:
        ctx = _Ctx(cursor)
        try:
            tag, used, controller, worker, failed = self._solve_tag(
                fhash, ctag, plan, cluster, ctx,
                is_fallback=False, zone_override=entry_zone,
                entry_zone=entry_zone,
            )
        except _NeedsScalar:
            return _Record(ctx.mod, cursor % ctx.mod)  # scalar marker
        rec = _Record(ctx.mod, cursor % ctx.mod)
        rec.delta = ctx.cur - cursor
        rec.proto = {
            "outcome": (
                self._scheduled_outcome
                if worker is not None
                else self._failed_outcome
            ),
            "worker": worker,
            "controller": controller,
            "tag": tag,
            "used_default_fallback": used,
            "zone_restriction": ctx.zr,
            "failed_by_policy": failed,
        }
        return rec

    def _solve_tag(
        self,
        fhash: int,
        ctag,
        plan,
        cluster: ClusterState,
        ctx: _Ctx,
        *,
        is_fallback: bool,
        zone_override: Optional[str],
        entry_zone: Optional[str],
    ):
        for _block_index, cblock in self._ordered(
            ctag.enumerated, ctag.strategy, fhash
        ):
            placed = self._solve_block(
                fhash, cblock, cluster, ctx, zone_override, entry_zone
            )
            if placed is not None:
                return ctag.tag, is_fallback, placed[0], placed[1], False
        if ctag.followup is FollowupKind.DEFAULT and not is_fallback:
            sticky = zone_override
            for label in ctag.sticky_same_labels:
                designated = cluster.controllers.get(label)
                if designated is not None:
                    sticky = designated.zone
                    break
            default_tag = plan.default
            if default_tag is not None and default_tag.tag != ctag.tag:
                return self._solve_tag(
                    fhash, default_tag, plan, cluster, ctx,
                    is_fallback=True, zone_override=sticky,
                    entry_zone=entry_zone,
                )
        return ctag.tag, is_fallback, None, None, True

    def _ordered(self, items, strategy: Strategy, fhash: int):
        if strategy is Strategy.BEST_FIRST or not items:
            return items
        if strategy is Strategy.PLATFORM:
            return [items[i] for i in coprime_order_cached(len(items), fhash)]
        if strategy is Strategy.WARM_FIRST:
            # Tag-level warm-first is a validation error; every reference
            # path degrades it to best_first, so mirror that here.
            return items
        if len(items) >= 2:
            raise _NeedsScalar  # random over ≥2 items draws
        return items  # random over one item: zero draws, identity order

    def _solve_block(
        self,
        fhash: int,
        cblock,
        cluster: ClusterState,
        ctx: _Ctx,
        zone_override: Optional[str],
        entry_zone: Optional[str],
    ) -> Optional[Tuple[str, str]]:
        if cblock.controller is None:
            if entry_zone is None:
                controllers = [
                    c for c in cluster.controllers.values() if c.available
                ]
            else:
                controllers = [
                    c for c in cluster.controllers.values()
                    if c.available and c.zone == entry_zone
                ]
            if not controllers:
                return None
            n = len(controllers)
            start = ctx.cur
            ctx.cur += 1
            ctx.mod = lcm(ctx.mod, n)
            for offset in range(n):
                controller = controllers[(start + offset) % n]
                placed = self._solve_block_on(
                    fhash, cblock, controller, zone_override, cluster
                )
                if placed is not None:
                    ctx.zr = zone_override
                    return placed
            return None

        controller, zone_restriction = self._solve_controller(
            cblock, cluster, ctx, entry_zone
        )
        if controller is None:
            return None
        effective = zone_restriction or zone_override
        ctx.zr = effective
        return self._solve_block_on(
            fhash, cblock, controller, effective, cluster
        )

    def _solve_controller(
        self,
        cblock,
        cluster: ClusterState,
        ctx: _Ctx,
        entry_zone: Optional[str],
    ) -> Tuple[Optional[ControllerState], Optional[str]]:
        clause = cblock.controller
        tol = clause.topology_tolerance
        designated = cluster.controllers.get(clause.label)
        if designated is not None and designated.available:
            if entry_zone is not None and tol is not TopologyTolerance.ALL:
                return designated, designated.zone
            return designated, None
        designated_zone = designated.zone if designated is not None else None
        if tol is TopologyTolerance.NONE:
            return None, None
        controllers = [c for c in cluster.controllers.values() if c.available]
        if not controllers:
            return None, None
        n = len(controllers)
        alternative = controllers[ctx.cur % n]
        ctx.cur += 1
        ctx.mod = lcm(ctx.mod, n)
        if tol is TopologyTolerance.SAME:
            if designated_zone is None:
                # The bump above already happened (mirrors the reference
                # path, which consumes the round-robin pick before
                # discovering the zone is unresolvable).
                return None, None
            return alternative, designated_zone
        return alternative, None

    def _solve_block_on(
        self,
        fhash: int,
        cblock,
        controller: ControllerState,
        zone_restriction: Optional[str],
        cluster: ClusterState,
    ) -> Optional[Tuple[str, str]]:
        engine = self._engine
        entry = cached_view_entry(
            cluster,
            controller.zone,
            engine.distribution,
            controller_name=controller.name,
            zone_restriction=zone_restriction,
        )
        bindex = entry.block_index(cblock)
        if not cblock.uses_sets:
            idx = bindex.wrk
            pos = self._solve_pick(idx, cblock.strategy, fhash, cluster)
            if pos is None:
                return None
            return controller.name, idx.workers[pos].name
        sets = cblock.sets
        n_items = len(sets)
        strategy = cblock.strategy
        indexes = bindex.sets
        if strategy is Strategy.BEST_FIRST or n_items <= 1:
            item_order: Sequence[int] = range(n_items)
        elif strategy is Strategy.PLATFORM:
            item_order = coprime_order_cached(n_items, fhash)
        elif strategy is Strategy.WARM_FIRST:
            # Stable warm partition over set items — same ordering (and
            # zero draws) as the scalar paths.
            item_order = sorted(
                range(n_items),
                key=lambda i: not indexes[i].has_warm(cluster, fhash),
            )
        else:
            raise _NeedsScalar  # random over ≥2 set items draws
        for ipos in item_order:
            pos = self._solve_pick(
                indexes[ipos], sets[ipos].strategy, fhash, cluster
            )
            if pos is not None:
                idx = indexes[ipos]
                return controller.name, idx.workers[pos].name
        return None

    def _solve_pick(
        self,
        idx: ItemIndex,
        strategy: Strategy,
        fhash: int,
        cluster: ClusterState,
    ) -> Optional[int]:
        avail = idx.refresh(cluster)
        if strategy is Strategy.RANDOM:
            n_local = idx.n_local
            n_foreign = idx.n - n_local
            if n_local >= 2 or n_foreign >= 2:
                raise _NeedsScalar  # a ≥2 tier draws even when saturated
            # ≤1-element tiers: pick_random degenerates to checking the
            # single position per tier, local first, zero draws.
            if n_local == 1 and avail & 1:
                return 0
            if n_foreign == 1 and (avail >> n_local) & 1:
                return n_local
            return None
        if not avail:
            return None
        if strategy is Strategy.PLATFORM:
            return self._pick_platform_vec(idx, avail, fhash)
        if strategy is Strategy.WARM_FIRST:
            # Pure bit ops, mirroring the scalar engine's pick: warm
            # locals, cold locals, warm foreigns, cold foreigns.
            warm = idx.warm_mask(cluster, fhash) & avail
            if warm:
                local = idx.local_mask
                wl = warm & local
                if wl:
                    return (wl & -wl).bit_length() - 1
                al = avail & local
                if al:
                    return (al & -al).bit_length() - 1
                return (warm & -warm).bit_length() - 1
        return (avail & -avail).bit_length() - 1  # BEST_FIRST

    # -- mask-plane kernel picks --------------------------------------------

    def _pick_platform_vec(
        self, idx: ItemIndex, avail: int, fhash: int
    ) -> Optional[int]:
        if self._churn:
            # Admission-corrected remainder of the batch: avail moves
            # per item, so plane reuse is nil — scalar chunk scan wins.
            return idx.pick_platform(avail, fhash)
        key = (idx.serial, avail)
        plane = self._planes.get(key)
        if plane is None:
            if len(self._planes) >= _PLANE_CACHE_LIMIT:
                self._planes.clear()
            plane = self._kernel_picks(idx, avail, self._batch_hashes)
            self._planes[key] = plane
        pick = plane.get(fhash)
        if pick is None:
            # A hash outside the current batch group (cache carried over
            # from an earlier batch): resolve its row alone.
            pick = self._kernel_picks(idx, avail, (fhash,))[fhash]
            plane[fhash] = pick
        return pick if pick >= 0 else None

    def _kernel_picks(
        self, idx: ItemIndex, avail: int, hashes: Tuple[int, ...]
    ) -> Dict[int, int]:
        """Resolve the whole hash group's platform picks in one kernel call.

        Stacks each hash's co-prime trial order into an int32 ``[m, L]``
        plane (-1 padded), views the availability mask as uint64 words,
        and lets :func:`select_first_available` take "first set bit in
        order" for every row at once — bit-identical to the scalar
        ``pick_platform`` scan over the same flat order.
        """
        np = self._np
        select = self._select
        if select is None:
            import numpy
            from repro.kernels.ops import select_first_available

            np = self._np = numpy
            select = self._select = select_first_available
        orders = [idx.platform_order(h) for h in hashes]
        width = max(len(o) for o in orders)
        if width == 0:
            return {h: -1 for h in hashes}
        plane = np.full((len(hashes), width), -1, dtype=np.int32)
        for row, order in enumerate(orders):
            plane[row, : len(order)] = order
        nwords = max(1, (idx.n + 63) >> 6)
        # Explicit little-endian dtype: the bytes are produced
        # little-endian, so a native-endian view would byte-swap the
        # mask words on a big-endian host.
        words = np.frombuffer(
            avail.to_bytes(nwords * 8, "little"), dtype="<u8"
        )
        picks = select(words, plane, backend=self._backend)
        return {h: int(p) for h, p in zip(hashes, picks)}
