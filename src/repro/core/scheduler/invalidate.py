"""Invalidate-condition evaluation (paper §3.3).

All invalidate options include, as a preliminary condition, the
unreachability of the worker. The three conditions:

* ``overload`` — the worker lacks resources to run the function. Maps to
  the platform health signal (OpenWhisk's "unhealthy invoker"; here the
  serving engine's slot-exhaustion/heartbeat state).
* ``capacity_used n%`` — load percentage threshold.
* ``max_concurrent_invocations n`` — buffered concurrent invocations
  threshold.

Resolution order of the condition applied to a worker item (paper §3.3):
per-``wrk``/per-``set`` condition ▸ enclosing block condition ▸ platform
default (``overload``).
"""
from __future__ import annotations

from typing import Optional

from repro.core.scheduler.state import WorkerState
from repro.core.tapp.ast import (
    CapacityUsed,
    Invalidate,
    MaxConcurrentInvocations,
    Overload,
)

DEFAULT_INVALIDATE: Invalidate = Overload()


def resolve_invalidate(
    item_level: Optional[Invalidate],
    block_level: Optional[Invalidate],
) -> Invalidate:
    """Inner condition overrides outer; fall back to the platform default."""
    if item_level is not None:
        return item_level
    if block_level is not None:
        return block_level
    return DEFAULT_INVALIDATE


def is_invalid(worker: WorkerState, condition: Invalidate) -> bool:
    """True iff the worker cannot host the execution under ``condition``."""
    if not worker.reachable:
        return True
    if isinstance(condition, Overload):
        return worker.overloaded
    if isinstance(condition, CapacityUsed):
        return worker.capacity_used_pct >= condition.percent
    if isinstance(condition, MaxConcurrentInvocations):
        return worker.concurrent >= condition.limit
    raise TypeError(f"unknown invalidate condition {condition!r}")


def invalid_reason(worker: WorkerState, condition: Invalidate) -> Optional[str]:
    """Human-readable reason the worker is invalid, or None if valid."""
    if not worker.reachable:
        return "unreachable"
    if isinstance(condition, Overload):
        if not worker.healthy:
            return "unhealthy"
        if worker.inflight >= worker.capacity_slots:
            return f"slots exhausted ({worker.inflight}/{worker.capacity_slots})"
        return None
    if isinstance(condition, CapacityUsed):
        if worker.capacity_used_pct >= condition.percent:
            return (
                f"capacity_used {worker.capacity_used_pct:.0f}% >= "
                f"{condition.percent:.0f}%"
            )
        return None
    if isinstance(condition, MaxConcurrentInvocations):
        if worker.concurrent >= condition.limit:
            return f"concurrent {worker.concurrent} >= {condition.limit}"
        return None
    raise TypeError(f"unknown invalidate condition {condition!r}")
