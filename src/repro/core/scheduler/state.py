"""Live cluster state consumed by the tAPP scheduler.

In the paper's OpenWhisk deployment this information is produced by the
*Watcher* (polling the Kubernetes API) and stored on an NFS share. Here it
is an in-process snapshot maintained by :mod:`repro.core.scheduler.watcher`;
on a real TPU fleet it would be fed by per-host agents reporting HBM use,
queue depth, and liveness heartbeats.

A *worker* is the unit of placement: in this framework, a model replica —
a mesh slice (a set of chips) that hosts one compiled model's weights and
serves invocations against it. The same abstraction covers the paper's
container-based invokers, which is what the discrete-event simulator
instantiates for the paper-table benchmarks.
"""
from __future__ import annotations

import dataclasses
import enum
import threading
from typing import Dict, FrozenSet, Iterable, List, Mapping, Optional, Sequence


class HealthState(enum.Enum):
    """Failure-detector verdict on one worker (PR 6).

    ``HEALTHY`` → ``SUSPECT`` when the heartbeat lease expires (the worker
    stays placeable but is deprioritized in candidate ordering);
    ``SUSPECT`` → ``DEAD`` when the lease stays expired past the dead
    threshold (the worker is excluded like a drain and its in-flight
    tickets are reconciled as evictions). A recovery heartbeat restores
    ``HEALTHY`` from either state. Orthogonal to the boolean ``healthy``
    platform signal: SUSPECT keeps ``healthy``/``reachable`` true, DEAD
    clears both.
    """

    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"


@dataclasses.dataclass
class WorkerState:
    """Mutable live state of one worker (model replica / invoker).

    Attributes:
      name: unique worker label (the tAPP ``wrk`` label).
      zone: physical topology zone (here: pod / ICI domain).
      sets: logical worker-set labels this worker belongs to (tAPP ``set``).
      capacity_slots: max concurrent invocations the worker can run.
      inflight: currently executing invocations.
      queued: buffered (not yet executing) invocations.
      capacity_used_pct: load percentage (CPU in the paper; HBM+slot
        occupancy here). Fed by the watcher.
      healthy: platform health signal (OpenWhisk "unhealthy invoker" API ~
        serving-engine heartbeat). ``overload`` invalidation triggers on
        ``not healthy`` or slot exhaustion.
      reachable: network reachability; unreachability is the *preliminary*
        invalidate condition for every policy (paper §3.3).
      resident_models: model ids whose weights are resident (data locality:
        scheduling onto a non-resident worker incurs a cold start).
      running_functions: multiset of admitted (buffered + executing)
        invocations by function name — the signal the affinity /
        anti-affinity constraints read. Fed by the controller runtime on
        admit/complete; volatile like ``inflight`` (never bumps the
        topology epoch).
      memory_bytes / memory_used_bytes: HBM capacity bookkeeping.
      perf_factor: relative execution-speed multiplier (1.0 = nominal);
        the simulator uses it for heterogeneous workers and stragglers.
    """

    name: str
    zone: str = "default"
    sets: FrozenSet[str] = frozenset()
    capacity_slots: int = 16
    inflight: int = 0
    inflight_by: Dict[str, int] = dataclasses.field(default_factory=dict)
    running_functions: Dict[str, int] = dataclasses.field(default_factory=dict)
    queued: int = 0
    capacity_used_pct: float = 0.0
    healthy: bool = True
    reachable: bool = True
    resident_models: FrozenSet[str] = frozenset()
    memory_bytes: int = 16 * 1024**3
    memory_used_bytes: int = 0
    perf_factor: float = 1.0
    # Failure-detector verdict (lease machinery in the watcher). SUSPECT
    # workers remain placeable but sort after healthy peers in every
    # candidate order; DEAD workers are structurally excluded.
    health: HealthState = HealthState.HEALTHY
    # Incarnation counter: bumped when the worker's in-flight tickets are
    # evicted wholesale (a crash / DEAD transition). Placements capture it
    # at admission so a ticket can never retire against a later
    # incarnation's counters.
    generation: int = 0
    # Warm-pool occupancy: function hash -> count of IDLE (reusable)
    # instances on this worker. Maintained by the platform lifecycle
    # manager (``platform/lifecycle.py``) — empty unless a lifecycle is
    # armed. Volatile like ``inflight`` (never bumps the topology epoch);
    # 0<->1 transitions are reported via
    # :meth:`ClusterState.note_worker_warmth` so the per-epoch candidate
    # indexes can refresh their warm bitmasks incrementally.
    warm_idle: Dict[int, int] = dataclasses.field(default_factory=dict)
    # Per-worker keep-alive override (seconds an IDLE instance survives);
    # None defers to the controller-/spec-level default. Volatile: set at
    # registration from WorkerSpec.keep_alive, read by the lifecycle.
    keep_alive: Optional[float] = None

    @property
    def suspect(self) -> bool:
        return self.health is HealthState.SUSPECT

    @property
    def dead(self) -> bool:
        return self.health is HealthState.DEAD

    @property
    def concurrent(self) -> int:
        """Buffered concurrent invocations (queued + running)."""
        return self.inflight + self.queued

    @property
    def overloaded(self) -> bool:
        return (not self.healthy) or self.inflight >= self.capacity_slots

    @property
    def load_fraction(self) -> float:
        if self.capacity_slots <= 0:
            return 1.0
        return self.inflight / self.capacity_slots

    def in_set(self, label: Optional[str]) -> bool:
        """Blank set label (None) matches every worker (paper §3.3)."""
        return label is None or label in self.sets

    def inflight_for(self, controller: str) -> int:
        """Admissions by one controller (its entitlement consumption)."""
        return self.inflight_by.get(controller, 0)

    def running_count(self, function: str) -> int:
        """Admitted invocations of ``function`` currently on this worker."""
        return self.running_functions.get(function, 0)

    def warm_for(self, fhash: int) -> bool:
        """True when an IDLE instance of the hashed function is poolable."""
        return self.warm_idle.get(fhash, 0) > 0


@dataclasses.dataclass
class ControllerState:
    """One controller (per-zone scheduler)."""

    name: str
    zone: str = "default"
    healthy: bool = True
    reachable: bool = True

    @property
    def available(self) -> bool:
        return self.healthy and self.reachable


# Volatile-load log compaction threshold: when a shard's log outgrows
# this, it is truncated and stale index consumers fall back to a full
# avail-mask rebuild (amortized O(1) per logged event).
_LOAD_LOG_LIMIT = 4096


class _LoadShard:
    """One zone's volatile-load event log (zone-local writes).

    Sharding the log per zone keeps federated entrypoints from
    serializing on — and, worse, replaying — each other's admission
    streams: a zone-restricted candidate index tracks only the shards
    its candidates live in, so churn in zone A never costs zone B's
    routing path a single replayed event.
    """

    __slots__ = ("log", "trimmed")

    def __init__(self) -> None:
        self.log: List[str] = []
        self.trimmed = 0

    @property
    def seq(self) -> int:
        """Absolute sequence number of the next event in this shard."""
        return self.trimmed + len(self.log)

    def note(self, name: str) -> None:
        log = self.log
        log.append(name)
        if len(log) > _LOAD_LOG_LIMIT:
            # Compaction *replaces* the list rather than clearing it in
            # place: lock-free readers that already grabbed a reference
            # replay a complete (merely stale) window instead of a
            # truncated one, and the advanced ``trimmed`` cursor pushes
            # them onto the full-recompute path on their next refresh.
            # Writer order (trimmed, then log) pairs with the readers'
            # capture order (trimmed, then log) so a torn read can only
            # look over-trimmed — which also lands on the recompute path.
            self.trimmed += len(log)
            self.log = []


@dataclasses.dataclass
class ClusterState:
    """A consistent snapshot of controllers + workers.

    The scheduler never mutates entries it did not create; the watcher owns
    the authoritative copy and hands out snapshots (the paper's NFS-stored
    mapping, §4.2).

    **Volatile-load contract:** mutations of the volatile worker fields
    (inflight counters, queue depth, capacity percentage, the
    running-function multiset) must be reported via
    :meth:`note_worker_load` — the watcher's ledger and heartbeat paths
    do this — so the per-epoch candidate indexes
    (:class:`~repro.core.scheduler.topology.BlockIndex`) can refresh the
    touched worker's availability bits in O(1) instead of rescanning.
    Structural changes go through :meth:`bump_topology_epoch` as before.
    """

    workers: Dict[str, WorkerState] = dataclasses.field(default_factory=dict)
    controllers: Dict[str, ControllerState] = dataclasses.field(default_factory=dict)
    version: int = 0
    # Bumped only on *structural* changes (membership, zones, sets,
    # reachability/health, capacity) — never on inflight counters. The
    # compiled scheduling fast path memoizes distribution views per epoch;
    # see :mod:`repro.core.scheduler.topology`.
    topology_epoch: int = 0
    view_cache: Dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    # Volatile-load event logs, sharded per zone: worker names whose
    # dynamic fields changed, in order, appended to the shard of the
    # worker's zone. Candidate indexes consume only the shards their
    # candidates span; see load_seq/note_worker_load.
    load_shards: Dict[str, _LoadShard] = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    # Advisory total of volatile-load events across every shard (the
    # cheap "anything at all changed?" signal; per-shard seqs are the
    # exact cursors).
    _load_total: int = 0
    # Merged journal of the same events, all zones interleaved in global
    # order (its seq always equals _load_total). Indexes whose candidates
    # span multiple zones replay this window — O(events since last sync)
    # — instead of scanning every zone shard for new cursors, which would
    # be O(zones) per decision even when nothing moved. Single-zone
    # indexes keep reading their zone shard, so the containment story
    # (foreign churn costs a zone-restricted index nothing) is unchanged.
    _load_journal: _LoadShard = dataclasses.field(
        default_factory=_LoadShard, repr=False, compare=False
    )
    # Guards _load_journal and _load_total. Zone shards are protected by
    # their zone's ledger lock (the watcher holds it around every
    # note_worker_load call), but the merged journal and the total are
    # written by *every* zone's entrypoint, so without a dedicated lock
    # two zones admitting concurrently can lose increments — a lost
    # increment makes index refresh see "nothing changed" and serve a
    # stale availability mask, and it permanently breaks the
    # ``journal.seq == _load_total`` invariant the multi-zone replay
    # window arithmetic depends on.
    _journal_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False
    )
    # Warm-pool event journal: ``(worker_name, fhash)`` entries appended
    # whenever a worker's IDLE-instance count for a function crosses the
    # 0<->1 boundary (the only transitions that can flip a warm-bitmask
    # bit). One merged journal, not zone-sharded: warm events exist only
    # when a lifecycle is armed and are far rarer than load events, so
    # replay cost is negligible — and expirations fire from a janitor,
    # not from a zone entrypoint, so there is no natural shard writer.
    _warm_journal: _LoadShard = dataclasses.field(
        default_factory=_LoadShard, repr=False, compare=False
    )
    # Advisory total of warm events (the warm analogue of _load_total).
    # Part of the batch router's memo validity token: a janitor expiry
    # changes warmth WITHOUT a load event, so load cursors alone would
    # replay stale warm-first outcomes.
    _warm_total: int = 0
    # Per-epoch memo for the derived topology queries (workers_in_set /
    # set_labels / zones); cleared with the view cache.
    _query_cache: Dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    # Lazily built zone → [WorkerState] map (insertion order preserved),
    # maintained incrementally on add_worker and dropped on removals /
    # zone moves; lets zone-restricted view rebuilds scan O(zone workers)
    # instead of the whole cluster.
    _zone_members: Optional[Dict[str, List[WorkerState]]] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def bump_topology_epoch(self, zone: Optional[str] = None) -> None:
        """Invalidate memoized topology views (structural change).

        ``zone=None`` (the conservative default) drops every cached view.
        Passing a zone scopes the eviction to entries that can actually
        see that zone's workers — zone-restricted entries of *other*
        zones survive, so a worker flapping in zone A never forces zone
        B's entrypoint to rebuild its candidate indexes (the Archipelago
        partitioned-invalidation property). The global epoch counter
        always advances: plan/derived-query memos stay conservative.
        """
        self.topology_epoch += 1
        if self.view_cache:
            if zone is None:
                self.view_cache.clear()
            else:
                stale = [
                    key
                    for key in self.view_cache
                    if key[3] is None or key[3] == zone
                ]
                for key in stale:
                    del self.view_cache[key]
        if self._query_cache:
            self._query_cache.clear()

    # -- volatile-load event log --------------------------------------------

    @property
    def load_seq(self) -> int:
        """Monotonic count of volatile-load events recorded so far."""
        return self._load_total

    @property
    def load_trimmed(self) -> int:
        """Total events dropped by compaction, summed across shards."""
        return sum(shard.trimmed for shard in self.load_shards.values())

    def load_shard(self, zone: str) -> _LoadShard:
        shard = self.load_shards.get(zone)
        if shard is None:
            shard = self.load_shards[zone] = _LoadShard()
        return shard

    def note_worker_load(self, name: str, zone: Optional[str] = None) -> None:
        """Record that ``name``'s volatile load fields changed.

        O(1) amortized: appends to the worker's zone shard, compacting a
        shard once it exceeds ``_LOAD_LOG_LIMIT`` (consumers whose cursor
        predates the compaction rebuild from scratch, which the limit
        amortizes). ``zone`` may be passed by callers that already hold
        the worker (the watcher's admission ledger) to skip the lookup.

        Thread contract: the caller must hold the worker's zone ledger
        lock (the watcher's admission/heartbeat paths do), which makes
        the zone-shard append single-writer. The merged journal and the
        event total are shared across zones and are updated under the
        cluster's journal lock, preserving ``journal.seq == _load_total``
        under concurrent multi-zone admission.
        """
        if zone is None:
            worker = self.workers.get(name)
            zone = worker.zone if worker is not None else ""
        shard = self.load_shards.get(zone)
        if shard is None:
            shard = self.load_shards[zone] = _LoadShard()
        # Inlined _LoadShard.note body: this runs once per ledger event
        # on the admission fast path, where the method call is
        # measurable against the ~µs decision budget. Compaction
        # replaces the list (see _LoadShard.note) so lock-free readers
        # never see a half-cleared window.
        log = shard.log
        log.append(name)
        if len(log) > _LOAD_LOG_LIMIT:
            shard.trimmed += len(log)
            shard.log = []
        with self._journal_lock:
            journal = self._load_journal
            log = journal.log
            log.append(name)
            if len(log) > _LOAD_LOG_LIMIT:
                journal.trimmed += len(log)
                journal.log = []
            self._load_total += 1

    # -- warm-pool event journal --------------------------------------------

    @property
    def warm_seq(self) -> int:
        """Monotonic count of warm-bit flip events recorded so far."""
        return self._warm_total

    def note_worker_warmth(self, name: str, fhash: int) -> None:
        """Record that ``name``'s warm bit for ``fhash`` flipped (0<->1).

        Called by the lifecycle manager under its own lock whenever an
        idle-instance count crosses the 0/1 boundary. The journal lock
        keeps ``journal.seq == _warm_total`` under concurrent callers,
        mirroring :meth:`note_worker_load`.
        """
        with self._journal_lock:
            journal = self._warm_journal
            log = journal.log
            log.append((name, fhash))
            if len(log) > _LOAD_LOG_LIMIT:
                journal.trimmed += len(log)
                journal.log = []
            self._warm_total += 1

    # -- membership ---------------------------------------------------------

    def add_worker(self, worker: WorkerState) -> None:
        if worker.name in self.workers:
            raise ValueError(f"duplicate worker {worker.name!r}")
        self.workers[worker.name] = worker
        if self._zone_members is not None:
            self._zone_members.setdefault(worker.zone, []).append(worker)
        self.version += 1
        self.bump_topology_epoch(worker.zone)

    def remove_worker(self, name: str) -> None:
        removed = self.workers.pop(name, None)
        self._zone_members = None
        self.version += 1
        self.bump_topology_epoch(removed.zone if removed is not None else None)

    def add_controller(self, controller: ControllerState) -> None:
        if controller.name in self.controllers:
            raise ValueError(f"duplicate controller {controller.name!r}")
        self.controllers[controller.name] = controller
        self.version += 1
        self.bump_topology_epoch()

    def remove_controller(self, name: str) -> None:
        self.controllers.pop(name, None)
        self.version += 1
        self.bump_topology_epoch()

    # -- queries -------------------------------------------------------------

    def worker_names(self) -> List[str]:
        return list(self.workers.keys())

    def workers_in_zone(self, zone: str) -> List[WorkerState]:
        return list(self.workers_by_zone(zone))

    def workers_by_zone(self, zone: str) -> Sequence[WorkerState]:
        """Workers of one zone, in cluster insertion order.

        Backed by an incrementally maintained per-zone map (rebuilt
        lazily after removals or zone moves), so zone-restricted view
        rebuilds cost O(zone workers) rather than O(cluster).
        """
        return self.zone_members().get(zone, ())

    def zone_members(self) -> Dict[str, List[WorkerState]]:
        """The full per-zone member map backing :meth:`workers_by_zone`
        (treat as read-only). Lets per-zone scans — e.g. the federation's
        dead-zone detection — iterate zones with early-out instead of
        walking every worker in the cluster."""
        members = self._zone_members
        if members is None:
            members = {}
            for worker in self.workers.values():
                members.setdefault(worker.zone, []).append(worker)
            self._zone_members = members
        return members

    def invalidate_zone_members(self) -> None:
        """Drop the per-zone member map (a worker changed zones)."""
        self._zone_members = None

    def workers_in_set(self, label: Optional[str]) -> List[WorkerState]:
        """Workers matching a tAPP set label; memoized per topology epoch
        (set membership is structural, so epoch bumps invalidate)."""
        hit = self._query_cache.get(("set", label))
        if hit is None:
            hit = tuple(w for w in self.workers.values() if w.in_set(label))
            self._query_cache[("set", label)] = hit
        return list(hit)

    def set_labels(self) -> List[str]:
        """All set labels in the deployment; memoized per topology epoch."""
        hit = self._query_cache.get("set_labels")
        if hit is None:
            labels: set = set()
            for w in self.workers.values():
                labels |= w.sets
            hit = tuple(sorted(labels))
            self._query_cache["set_labels"] = hit
        return list(hit)

    def zones(self) -> List[str]:
        """All zones hosting a worker or controller; memoized per epoch."""
        hit = self._query_cache.get("zones")
        if hit is None:
            zs = {w.zone for w in self.workers.values()}
            zs |= {c.zone for c in self.controllers.values()}
            hit = tuple(sorted(zs))
            self._query_cache["zones"] = hit
        return list(hit)

    def controllers_in_zone(self, zone: str) -> List[ControllerState]:
        return [c for c in self.controllers.values() if c.zone == zone]

    def controller_names(self) -> List[str]:
        return list(self.controllers.keys())


def make_cluster(
    workers: Iterable[Mapping],
    controllers: Iterable[Mapping] = (),
) -> ClusterState:
    """Convenience constructor from plain dicts (used by tests/configs)."""
    cluster = ClusterState()
    for spec in workers:
        spec = dict(spec)
        if "sets" in spec:
            spec["sets"] = frozenset(spec["sets"])
        if "resident_models" in spec:
            spec["resident_models"] = frozenset(spec["resident_models"])
        cluster.add_worker(WorkerState(**spec))
    for spec in controllers:
        cluster.add_controller(ControllerState(**dict(spec)))
    return cluster
