"""Item-selection strategies (paper §3.3).

* ``best_first`` — order of appearance; send to the first valid item.
* ``random`` — fair random choice among valid items.
* ``platform`` — the host platform's default schedule. Faithful to
  OpenWhisk's *co-prime scheduling* (paper §2, footnotes 5–6): a function is
  hashed to a primary index ``hash % n``; on invalidation the index steps by
  a fixed *step size* that is co-prime with ``n``, cycling through all items.

Strategies are implemented as *orderings*: given the candidate items and an
invocation context, they yield the order in which candidates are tried. The
engine then applies invalidation in that order, which uniformly implements
"pick first valid" for all three strategies.
"""
from __future__ import annotations

import functools
import hashlib
import random as _random
from typing import List, Optional, Sequence, Tuple, TypeVar

from repro.core.tapp.ast import Strategy

T = TypeVar("T")


def stable_hash(text: str) -> int:
    """Deterministic 64-bit hash (Python's ``hash`` is salted per-process)."""
    return int.from_bytes(
        hashlib.blake2b(text.encode("utf-8"), digest_size=8).digest(), "big"
    )


def _coprime_step(hash_value: int, n: int) -> int:
    """Smallest step > 1 co-prime with ``n`` derived from the hash (1 if n<=2)."""
    if n <= 2:
        return 1
    import math

    candidates = [s for s in range(2, n) if math.gcd(s, n) == 1]
    if not candidates:
        return 1
    return candidates[hash_value % len(candidates)]


@functools.lru_cache(maxsize=8192)
def coprime_order_cached(n: int, hash_value: int) -> Tuple[int, ...]:
    """Memoized co-prime schedule.

    The permutation is a pure function of ``(n, hash)``; real deployments
    see a bounded set of functions and cluster sizes, so the co-prime step
    search (O(n log n)) amortizes to a dict hit on the scheduling hot path.
    """
    if n <= 0:
        return ()
    primary = hash_value % n
    step = _coprime_step(hash_value, n)
    order, idx = [], primary
    for _ in range(n):
        order.append(idx)
        idx = (idx + step) % n
    # Co-primality guarantees a full cycle; assert in debug builds.
    assert len(set(order)) == n, (n, step, order)
    return tuple(order)


def coprime_order(n: int, hash_value: int) -> List[int]:
    """OpenWhisk co-prime schedule: primary ``hash % n``, then step cycles.

    The step size is co-prime with ``n`` so the cycle visits every index
    exactly once.
    """
    return list(coprime_order_cached(n, hash_value))


def order_candidates(
    items: Sequence[T],
    strategy: Strategy,
    *,
    rng: Optional[_random.Random] = None,
    function_hash: int = 0,
) -> List[T]:
    """Return ``items`` in the order the strategy would try them."""
    items = list(items)
    if not items:
        return []
    if strategy is Strategy.BEST_FIRST:
        return items
    if strategy is Strategy.RANDOM:
        rng = rng or _random.Random()
        shuffled = list(items)
        rng.shuffle(shuffled)
        return shuffled
    if strategy is Strategy.PLATFORM:
        return [items[i] for i in coprime_order_cached(len(items), function_hash)]
    raise ValueError(f"unknown strategy {strategy!r}")
