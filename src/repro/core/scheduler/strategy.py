"""Item-selection strategies (paper §3.3).

* ``best_first`` — order of appearance; send to the first valid item.
* ``random`` — fair random choice among valid items.
* ``platform`` — the host platform's default schedule. Faithful to
  OpenWhisk's *co-prime scheduling* (paper §2, footnotes 5–6): a function is
  hashed to a primary index ``hash % n``; on invalidation the index steps by
  a fixed *step size* that is co-prime with ``n``, cycling through all items.

Strategies are implemented as *orderings*: given the candidate items and an
invocation context, they yield the order in which candidates are tried. The
engine then applies invalidation in that order, which uniformly implements
"pick first valid" for all three strategies.

Orderings are consumed **lazily** (:func:`iter_ordered`). This matters
for ``random``: a lazily-evaluated Fisher–Yates draw
(:func:`iter_random`) yields one uniformly-chosen remaining item per
step, so a decision that accepts the first candidate consumes O(1) RNG
draws instead of paying a full O(n) shuffle. Both the interpreter and
the compiled engine (including its indexed fast path) consume random
orderings through the same draw sequence, so their RNG streams — and
therefore placements and traces — stay bit-identical. The draw uses
:func:`randbelow` (our own getrandbits rejection loop) rather than
``random.Random.shuffle`` so the stream is stable across CPython
versions.
"""
from __future__ import annotations

import functools
import hashlib
import random as _random
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple, TypeVar

from repro.core.tapp.ast import Strategy

T = TypeVar("T")


def stable_hash(text: str) -> int:
    """Deterministic 64-bit hash (Python's ``hash`` is salted per-process)."""
    return int.from_bytes(
        hashlib.blake2b(text.encode("utf-8"), digest_size=8).digest(), "big"
    )


def _coprime_step(hash_value: int, n: int) -> int:
    """Smallest step > 1 co-prime with ``n`` derived from the hash (1 if n<=2)."""
    if n <= 2:
        return 1
    import math

    candidates = [s for s in range(2, n) if math.gcd(s, n) == 1]
    if not candidates:
        return 1
    return candidates[hash_value % len(candidates)]


@functools.lru_cache(maxsize=8192)
def coprime_order_cached(n: int, hash_value: int) -> Tuple[int, ...]:
    """Memoized co-prime schedule.

    The permutation is a pure function of ``(n, hash)``; real deployments
    see a bounded set of functions and cluster sizes, so the co-prime step
    search (O(n log n)) amortizes to a dict hit on the scheduling hot path.
    """
    if n <= 0:
        return ()
    primary = hash_value % n
    step = _coprime_step(hash_value, n)
    order, idx = [], primary
    for _ in range(n):
        order.append(idx)
        idx = (idx + step) % n
    # Co-primality guarantees a full cycle; assert in debug builds.
    assert len(set(order)) == n, (n, step, order)
    return tuple(order)


def coprime_order(n: int, hash_value: int) -> List[int]:
    """OpenWhisk co-prime schedule: primary ``hash % n``, then step cycles.

    The step size is co-prime with ``n`` so the cycle visits every index
    exactly once.
    """
    return list(coprime_order_cached(n, hash_value))


def randbelow(getrandbits, n: int) -> int:
    """Uniform int in ``[0, n)`` via getrandbits rejection sampling.

    The draw discipline every random ordering in the scheduler shares;
    implemented here (rather than leaning on ``Random._randbelow``) so
    the consumed bit stream is identical across CPython versions and
    across every evaluation path.
    """
    if n <= 1:
        return 0
    k = n.bit_length()
    r = getrandbits(k)
    while r >= n:
        r = getrandbits(k)
    return r


def iter_random(items: Sequence[T], rng: _random.Random) -> Iterator[T]:
    """Yield ``items`` in a uniformly random order, lazily.

    Incremental Fisher–Yates: each step draws one :func:`randbelow` and
    yields the item swapped into the current tail slot, so consuming the
    first ``k`` elements costs exactly ``k`` draws (the final element is
    free). Fully consumed, the sequence is a uniform permutation and the
    RNG stream equals a full Fisher–Yates shuffle — which is what makes
    partial consumption (stop at first valid candidate) free to early-out
    without desynchronizing any other evaluation path.
    """
    arr = list(items)
    getrandbits = rng.getrandbits
    for i in range(len(arr) - 1, 0, -1):
        j = randbelow(getrandbits, i + 1)
        arr[i], arr[j] = arr[j], arr[i]
        yield arr[i]
    if arr:
        yield arr[0]


def iter_ordered(
    items: Sequence[T],
    strategy: Strategy,
    *,
    rng: Optional[_random.Random] = None,
    function_hash: int = 0,
) -> Iterable[T]:
    """``items`` in strategy order, as a lazily-consumed iterable.

    The engine's ordering entry point: ``best_first`` and ``platform``
    consume no RNG; ``random`` draws lazily via :func:`iter_random`, so
    RNG consumption is proportional to candidates *tried*, not candidates
    *available*.
    """
    if strategy is Strategy.BEST_FIRST or not items:
        return items
    if strategy is Strategy.RANDOM:
        return iter_random(items, rng or _random.Random())
    if strategy is Strategy.PLATFORM:
        order = coprime_order_cached(len(items), function_hash)
        return (items[i] for i in order)
    if strategy is Strategy.WARM_FIRST:
        # Warm-first is warmth-aware and is ordered at the engine's call
        # sites (it needs worker pool state this module never sees). The
        # only route here is a tag-level warm-first — a validation error
        # — so degrade to the best_first identity order.
        return items
    raise ValueError(f"unknown strategy {strategy!r}")


def order_candidates(
    items: Sequence[T],
    strategy: Strategy,
    *,
    rng: Optional[_random.Random] = None,
    function_hash: int = 0,
) -> List[T]:
    """Return ``items`` in the order the strategy would try them.

    Eager counterpart of :func:`iter_ordered` (kept for callers that
    want a list); materializing a ``random`` ordering consumes the full
    draw sequence, exactly like exhausting the lazy iterator.
    """
    return list(
        iter_ordered(items, strategy, rng=rng, function_hash=function_hash)
    )
