"""Topology-aware function-execution scheduler (the paper's control plane)."""
from repro.core.scheduler.constraints import (
    DEFAULT_INVALIDATE,
    ConstraintSpec,
    compile_spec,
    constraint_reason,
    invalid_reason,
    is_invalid,
    resolve_constraints,
    resolve_invalidate,
    spec_predicate,
    spec_violated,
)
from repro.core.scheduler.controller import Admission, AdmissionError, ControllerRuntime
from repro.core.scheduler.engine import (
    Invocation,
    Outcome,
    ScheduleDecision,
    TappEngine,
    TraceEvent,
)
from repro.core.scheduler.gateway import Gateway, GatewayStats
from repro.core.scheduler.state import (
    ClusterState,
    ControllerState,
    WorkerState,
    make_cluster,
)
from repro.core.scheduler.strategy import (
    coprime_order,
    coprime_order_cached,
    order_candidates,
    stable_hash,
)
from repro.core.scheduler.topology import (
    DistributionPolicy,
    ViewCacheEntry,
    WorkerView,
    cached_view_entry,
    distribution_view,
)
from repro.core.scheduler.vanilla import VanillaScheduler
from repro.core.scheduler.watcher import Watcher

__all__ = [
    "Admission",
    "AdmissionError",
    "ClusterState",
    "ConstraintSpec",
    "ControllerRuntime",
    "ControllerState",
    "DEFAULT_INVALIDATE",
    "DistributionPolicy",
    "compile_spec",
    "constraint_reason",
    "resolve_constraints",
    "spec_predicate",
    "spec_violated",
    "Gateway",
    "GatewayStats",
    "Invocation",
    "Outcome",
    "ScheduleDecision",
    "TappEngine",
    "TraceEvent",
    "VanillaScheduler",
    "ViewCacheEntry",
    "Watcher",
    "WorkerState",
    "WorkerView",
    "cached_view_entry",
    "coprime_order",
    "coprime_order_cached",
    "distribution_view",
    "invalid_reason",
    "is_invalid",
    "make_cluster",
    "order_candidates",
    "resolve_invalidate",
    "stable_hash",
]
