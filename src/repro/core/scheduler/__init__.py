"""Topology-aware function-execution scheduler (the paper's control plane).

The curated public surface of the scheduling layer. Application code
should normally sit one level higher, on
:class:`repro.core.platform.TappPlatform`, which owns the wiring of
watcher + gateway + controller runtime; the names exported here are the
building blocks (state, engine, constraint layer, topology views) that
the platform composes and tests exercise directly.

Legacy constraint helpers (``is_invalid``, ``invalid_reason``,
``resolve_invalidate``) predate the composable constraint layer; they
remain importable via a module-level ``__getattr__`` that emits a
``DeprecationWarning`` — use :mod:`repro.core.scheduler.constraints`
(``resolve_constraints`` / ``constraint_reason`` / ``compile_spec``).
"""
import warnings as _warnings

from repro.core.scheduler.constraints import (
    DEFAULT_INVALIDATE,
    ConstraintSpec,
    compile_spec,
    constraint_reason,
    resolve_constraints,
    spec_predicate,
    spec_violated,
    split_spec,
)
from repro.core.scheduler.controller import Admission, AdmissionError, ControllerRuntime
from repro.core.scheduler.engine import (
    Invocation,
    Outcome,
    ScheduleDecision,
    TappEngine,
    TraceEvent,
)
from repro.core.scheduler.gateway import (
    Gateway,
    GatewayStats,
    ZoneGateway,
    forward_targets,
)
from repro.core.scheduler.state import (
    ClusterState,
    ControllerState,
    WorkerState,
    make_cluster,
)
from repro.core.scheduler.strategy import (
    coprime_order,
    coprime_order_cached,
    iter_ordered,
    iter_random,
    order_candidates,
    stable_hash,
)
from repro.core.scheduler.topology import (
    BlockIndex,
    DistributionPolicy,
    ItemIndex,
    ViewCacheEntry,
    WorkerView,
    cached_view_entry,
    distribution_view,
)
from repro.core.scheduler.vanilla import VanillaScheduler
from repro.core.scheduler.watcher import Watcher

__all__ = [
    "Admission",
    "AdmissionError",
    "BlockIndex",
    "ClusterState",
    "ConstraintSpec",
    "ControllerRuntime",
    "ControllerState",
    "DEFAULT_INVALIDATE",
    "DistributionPolicy",
    "Gateway",
    "GatewayStats",
    "Invocation",
    "ItemIndex",
    "Outcome",
    "ScheduleDecision",
    "TappEngine",
    "TraceEvent",
    "VanillaScheduler",
    "ViewCacheEntry",
    "Watcher",
    "WorkerState",
    "WorkerView",
    "ZoneGateway",
    "cached_view_entry",
    "compile_spec",
    "constraint_reason",
    "coprime_order",
    "coprime_order_cached",
    "distribution_view",
    "forward_targets",
    "iter_ordered",
    "iter_random",
    "make_cluster",
    "order_candidates",
    "resolve_constraints",
    "spec_predicate",
    "spec_violated",
    "split_spec",
    "stable_hash",
]

# Legacy shims kept importable (with a deprecation signal) for one more
# release cycle; deliberately NOT in __all__.
_DEPRECATED = ("is_invalid", "invalid_reason", "resolve_invalidate")


def __getattr__(name: str):
    if name in _DEPRECATED:
        _warnings.warn(
            f"repro.core.scheduler.{name} is deprecated; use the constraint "
            f"layer (repro.core.scheduler.constraints: resolve_constraints / "
            f"constraint_reason / compile_spec) instead",
            DeprecationWarning,
            stacklevel=2,
        )
        from repro.core.scheduler import constraints

        return getattr(constraints, name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}"
    )
