"""The platform gateway (the paper's modified Nginx, §4.3).

The gateway is the single entry point: it extracts the policy tag from an
invocation, consults the cached tAPP script, and resolves the invocation
through the :class:`TappEngine`. Without a script it falls back to the
vanilla round-robin/co-prime baseline — exactly the paper's behaviour
("when no tAPP script is provided, it falls back to the built-in
round-robin").

Caching model (paper §4.3/§4.5): the gateway keeps a local copy of the
script and the label mapping, and re-pulls from the watcher only when the
watcher bumps a version — mirroring the NFS-store + cache-invalidation
design.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.scheduler.engine import (
    Invocation,
    ScheduleDecision,
    TappEngine,
)
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.scheduler.vanilla import VanillaScheduler
from repro.core.scheduler.watcher import Watcher
from repro.core.tapp.ast import TappScript


@dataclasses.dataclass
class GatewayStats:
    routed: int = 0
    tapp_routed: int = 0
    vanilla_routed: int = 0
    failed: int = 0
    script_reloads: int = 0


class Gateway:
    def __init__(
        self,
        watcher: Watcher,
        *,
        distribution: DistributionPolicy = DistributionPolicy.DEFAULT,
        seed: Optional[int] = None,
        compiled: bool = True,
    ) -> None:
        self._watcher = watcher
        self._engine = TappEngine(distribution, seed=seed, compiled=compiled)
        self._vanilla = VanillaScheduler()
        self._cached_script: Optional[TappScript] = None
        self._cached_version = -1
        self.stats = GatewayStats()
        watcher.subscribe(self._on_event)

    # -- cache management ---------------------------------------------------------

    def _on_event(self, kind: str) -> None:
        if kind == "script":
            # Invalidate only; the refresh happens lazily on the next request.
            self._cached_version = -1

    def _script(self) -> Optional[TappScript]:
        version = self._watcher.script_version
        if version != self._cached_version:
            self._cached_script = self._watcher.script
            self._cached_version = version
            self.stats.script_reloads += 1
        return self._cached_script

    # -- routing --------------------------------------------------------------------

    def route(
        self, invocation: Invocation, *, trace: bool = False
    ) -> ScheduleDecision:
        self.stats.routed += 1
        script = self._script()
        cluster = self._watcher.cluster
        if script is None or not script.tags:
            decision = self._vanilla.schedule(invocation, cluster, trace=trace)
            self.stats.vanilla_routed += 1
        else:
            decision = self._engine.schedule(
                invocation, script, cluster, trace=trace
            )
            self.stats.tapp_routed += 1
        if not decision.scheduled:
            self.stats.failed += 1
        return decision

    @property
    def compiled(self) -> bool:
        """Whether this gateway's engine runs the compiled fast path."""
        return self._engine.compiled

    def prime(self, script: TappScript, plan) -> None:
        """Seed the engine's plan cache for a freshly-published script so
        the first routed decision does not pay compilation (no-op on the
        interpreter path)."""
        if self._engine.compiled:
            self._engine.adopt_plan(script, plan)

    def prewarm(self) -> int:
        """Build the plan's candidate indexes against the live topology.

        The indexed fast path builds views, block indexes, and
        availability masks lazily on first use; after a policy swap or a
        topology-epoch bump that lazy build lands on live traffic.
        Prewarming walks every (controller × compiled block) pair of the
        current plan — including the zone-restricted entries a
        ``topology_tolerance: same`` clause (or its sticky followup)
        routes through when its designated controller is unavailable —
        so the next decision is index-warm on the unrestricted paths and
        the statically-knowable restricted ones. Returns the number of
        block indexes touched (0 when there is no script or on the
        interpreter path, which has no indexes).
        """
        if not self._engine.compiled:
            return 0
        script = self._script()
        if script is None or not script.tags:
            return 0
        from repro.core.scheduler.topology import cached_view_entry
        from repro.core.tapp.ast import TopologyTolerance

        cluster = self._watcher.cluster
        plan = self._engine.compiled_plan(script)
        # Zone restrictions that evaluation can impose: a tolerance=same
        # clause whose designated controller is known pins candidates to
        # that controller's zone (directly, or via the sticky followup).
        sticky_zones = set()
        for ctag in plan.tags.values():
            for cblock in ctag.blocks:
                clause = cblock.controller
                if (
                    clause is not None
                    and clause.topology_tolerance is TopologyTolerance.SAME
                ):
                    designated = cluster.controllers.get(clause.label)
                    if designated is not None:
                        sticky_zones.add(designated.zone)
        warmed = 0
        for controller in cluster.controllers.values():
            for restriction in (None, *sorted(sticky_zones)):
                entry = cached_view_entry(
                    cluster,
                    controller.zone,
                    self._engine.distribution,
                    controller_name=controller.name,
                    zone_restriction=restriction,
                )
                for ctag in plan.tags.values():
                    for cblock in ctag.blocks:
                        entry.block_index(cblock)
                        warmed += 1
        return warmed

    def probe(self, invocation: Invocation) -> ScheduleDecision:
        """Evaluate an invocation with a full trace, without counting it.

        The observability path behind ``TappPlatform.explain``: identical
        policy evaluation to :meth:`route` (same engine), but genuinely
        side-effect-free — no stats accounting (the authoritative watcher
        script is read directly rather than through the reload-counting
        cache), and the engine's RNG stream and round-robin controller
        cursors are restored afterwards, so a probe between two real
        decisions never changes what the second one picks (seeded runs
        stay reproducible even under ``strategy: random``).
        """
        script = self._watcher.script
        cluster = self._watcher.cluster
        if script is None or not script.tags:
            state = self._vanilla.scheduling_state()
            try:
                return self._vanilla.schedule(invocation, cluster, trace=True)
            finally:
                self._vanilla.restore_scheduling_state(state)
        state = self._engine.scheduling_state()
        try:
            return self._engine.schedule(invocation, script, cluster, trace=True)
        finally:
            self._engine.restore_scheduling_state(state)

    def route_batch(
        self,
        invocations,
        *,
        trace: bool = False,
        on_decision=None,
    ):
        """Route a batch of invocations against one script/snapshot pull.

        The script version check and plan compilation happen once for the
        whole batch; decisions are made in order and ``on_decision`` fires
        after each one (before the next is evaluated), so callers that
        admit placements inside the callback get results identical to a
        sequence of :meth:`route` calls.
        """
        script = self._script()
        cluster = self._watcher.cluster

        def _account(invocation: Invocation, decision: ScheduleDecision) -> None:
            self.stats.routed += 1
            if script is None or not script.tags:
                self.stats.vanilla_routed += 1
            else:
                self.stats.tapp_routed += 1
            if not decision.scheduled:
                self.stats.failed += 1
            if on_decision is not None:
                on_decision(invocation, decision)

        if script is None or not script.tags:
            decisions = []
            for invocation in invocations:
                decision = self._vanilla.schedule(
                    invocation, cluster, trace=trace
                )
                _account(invocation, decision)
                decisions.append(decision)
            return decisions
        return self._engine.schedule_batch(
            invocations, script, cluster, trace=trace, on_decision=_account
        )
