"""The platform gateway (the paper's modified Nginx, §4.3).

The gateway is the single entry point: it extracts the policy tag from an
invocation, consults the cached tAPP script, and resolves the invocation
through the :class:`TappEngine`. Without a script it falls back to the
vanilla round-robin/co-prime baseline — exactly the paper's behaviour
("when no tAPP script is provided, it falls back to the built-in
round-robin").

Caching model (paper §4.3/§4.5): the gateway keeps a local copy of the
script and the label mapping, and re-pulls from the watcher only when the
watcher bumps a version — mirroring the NFS-store + cache-invalidation
design.

**Federation (PR 5).** A :class:`ZoneGateway` is a gateway bound to one
zone: it routes with ``entry_zone`` set, so the evaluation is the
semi-autonomous per-zone scheduler of the Archipelago shape
(arXiv:1911.09849) — zone-local controllers and workers first. When the
zone-local pass fails, :func:`forward_targets` derives, from the
policy's ``topology_tolerance`` clauses, which zones the invocation may
be forwarded to (and in what order); the federation façade walks them.
All zone gateways of a federation share one watcher and therefore one
epoch-cached view/index store — the per-zone candidate indexes are just
the ``zone_restriction``-keyed entries of that store.
"""
from __future__ import annotations

import dataclasses
from typing import FrozenSet, List, Optional, Sequence

from repro.core.scheduler.engine import (
    Invocation,
    ScheduleDecision,
    TappEngine,
)
from repro.core.scheduler.state import ClusterState
from repro.core.scheduler.topology import DistributionPolicy
from repro.core.scheduler.vanilla import VanillaScheduler
from repro.core.scheduler.watcher import Watcher
from repro.core.tapp.ast import (
    DEFAULT_TAG,
    FollowupKind,
    TappScript,
    TopologyTolerance,
)


@dataclasses.dataclass
class GatewayStats:
    routed: int = 0
    tapp_routed: int = 0
    vanilla_routed: int = 0
    failed: int = 0
    script_reloads: int = 0


class Gateway:
    def __init__(
        self,
        watcher: Watcher,
        *,
        distribution: DistributionPolicy = DistributionPolicy.DEFAULT,
        seed: Optional[int] = None,
        compiled: bool = True,
    ) -> None:
        self._watcher = watcher
        self._engine = TappEngine(distribution, seed=seed, compiled=compiled)
        self._vanilla = VanillaScheduler()
        self._cached_script: Optional[TappScript] = None
        self._cached_version = -1
        self.stats = GatewayStats()
        watcher.subscribe(self._on_event)

    # -- cache management ---------------------------------------------------------

    def _on_event(self, kind: str) -> None:
        if kind == "script":
            # Invalidate only; the refresh happens lazily on the next request.
            self._cached_version = -1

    def _script(self) -> Optional[TappScript]:
        version = self._watcher.script_version
        if version != self._cached_version:
            self._cached_script = self._watcher.script
            self._cached_version = version
            self.stats.script_reloads += 1
        return self._cached_script

    # -- routing --------------------------------------------------------------------

    def route(
        self,
        invocation: Invocation,
        *,
        trace: bool = False,
        entry_zone: Optional[str] = None,
        script: Optional[TappScript] = None,
    ) -> ScheduleDecision:
        """Route one invocation. ``script`` overrides the published
        script for this decision only (the brownout-degraded plan, PR 9);
        when omitted the watcher-cached script is used."""
        self.stats.routed += 1
        if script is None:
            script = self._script()
        cluster = self._watcher.cluster
        if script is None or not script.tags:
            decision = self._vanilla.schedule(
                invocation, cluster, trace=trace, entry_zone=entry_zone
            )
            self.stats.vanilla_routed += 1
        else:
            decision = self._engine.schedule(
                invocation, script, cluster, trace=trace,
                entry_zone=entry_zone,
            )
            self.stats.tapp_routed += 1
        if not decision.scheduled:
            self.stats.failed += 1
        return decision

    @property
    def compiled(self) -> bool:
        """Whether this gateway's engine runs the compiled fast path."""
        return self._engine.compiled

    @property
    def distribution(self) -> DistributionPolicy:
        """The distribution policy this gateway's engine evaluates under."""
        return self._engine.distribution

    def prime(self, script: TappScript, plan) -> None:
        """Seed the engine's plan cache for a freshly-published script so
        the first routed decision does not pay compilation (no-op on the
        interpreter path)."""
        if self._engine.compiled:
            self._engine.adopt_plan(script, plan)

    def prewarm(self, *, extra_restrictions: Sequence[str] = ()) -> int:
        """Build the plan's candidate indexes against the live topology.

        The indexed fast path builds views, block indexes, and
        availability masks lazily on first use; after a policy swap or a
        topology-epoch bump that lazy build lands on live traffic.
        Prewarming walks every (controller × compiled block) pair of the
        current plan — including the zone-restricted entries a
        ``topology_tolerance: same`` clause (or its sticky followup)
        routes through when its designated controller is unavailable —
        so the next decision is index-warm on the unrestricted paths and
        the statically-knowable restricted ones. ``extra_restrictions``
        adds further zone restrictions to warm (a :class:`ZoneGateway`
        passes its own zone — the entry-local view its every decision
        starts from). Returns the number of block indexes touched (0 when
        there is no script or on the interpreter path, which has no
        indexes).
        """
        if not self._engine.compiled:
            return 0
        script = self._script()
        if script is None or not script.tags:
            return 0
        from repro.core.scheduler.topology import cached_view_entry

        cluster = self._watcher.cluster
        plan = self._engine.compiled_plan(script)
        # Zone restrictions that evaluation can impose: a tolerance=same
        # clause whose designated controller is known pins candidates to
        # that controller's zone (directly, or via the sticky followup).
        sticky_zones = set(extra_restrictions)
        for ctag in plan.tags.values():
            for cblock in ctag.blocks:
                clause = cblock.controller
                if (
                    clause is not None
                    and clause.topology_tolerance is TopologyTolerance.SAME
                ):
                    designated = cluster.controllers.get(clause.label)
                    if designated is not None:
                        sticky_zones.add(designated.zone)
        warmed = 0
        for controller in cluster.controllers.values():
            for restriction in (None, *sorted(sticky_zones)):
                entry = cached_view_entry(
                    cluster,
                    controller.zone,
                    self._engine.distribution,
                    controller_name=controller.name,
                    zone_restriction=restriction,
                )
                for ctag in plan.tags.values():
                    for cblock in ctag.blocks:
                        entry.block_index(cblock)
                        warmed += 1
        return warmed

    def probe(
        self, invocation: Invocation, *, entry_zone: Optional[str] = None
    ) -> ScheduleDecision:
        """Evaluate an invocation with a full trace, without counting it.

        The observability path behind ``TappPlatform.explain``: identical
        policy evaluation to :meth:`route` (same engine), but genuinely
        side-effect-free — no stats accounting (the authoritative watcher
        script is read directly rather than through the reload-counting
        cache), and the engine's RNG stream and round-robin controller
        cursors are restored afterwards, so a probe between two real
        decisions never changes what the second one picks (seeded runs
        stay reproducible even under ``strategy: random``).
        """
        script = self._watcher.script
        cluster = self._watcher.cluster
        if script is None or not script.tags:
            state = self._vanilla.scheduling_state()
            try:
                return self._vanilla.schedule(
                    invocation, cluster, trace=True, entry_zone=entry_zone
                )
            finally:
                self._vanilla.restore_scheduling_state(state)
        state = self._engine.scheduling_state()
        try:
            return self._engine.schedule(
                invocation, script, cluster, trace=True,
                entry_zone=entry_zone,
            )
        finally:
            self._engine.restore_scheduling_state(state)

    def route_batch(
        self,
        invocations,
        *,
        trace: bool = False,
        entry_zone: Optional[str] = None,
        on_decision=None,
    ):
        """Route a batch of invocations against one script/snapshot pull.

        The script version check and plan compilation happen once for the
        whole batch; decisions are made in order and ``on_decision`` fires
        after each one (before the next is evaluated), so callers that
        admit placements inside the callback get results identical to a
        sequence of :meth:`route` calls.
        """
        script = self._script()
        cluster = self._watcher.cluster

        def _account(invocation: Invocation, decision: ScheduleDecision) -> None:
            self.stats.routed += 1
            if script is None or not script.tags:
                self.stats.vanilla_routed += 1
            else:
                self.stats.tapp_routed += 1
            if not decision.scheduled:
                self.stats.failed += 1
            if on_decision is not None:
                on_decision(invocation, decision)

        if script is None or not script.tags:
            decisions = []
            for invocation in invocations:
                decision = self._vanilla.schedule(
                    invocation, cluster, trace=trace, entry_zone=entry_zone
                )
                _account(invocation, decision)
                decisions.append(decision)
            return decisions
        return self._engine.schedule_batch(
            invocations, script, cluster, trace=trace,
            entry_zone=entry_zone, on_decision=_account,
        )


class ZoneGateway(Gateway):
    """A gateway bound to one federation zone (a per-zone entrypoint).

    Routing defaults to the zone-local pass: controller-less blocks use
    only this zone's controllers and candidate workers are restricted to
    this zone, while designated-controller blocks follow their
    ``topology_tolerance`` — ``none``/``same`` pinned to the designated
    home zone, ``all`` under the entry restriction (see the engine's
    entry-zone contract). The federation
    façade calls :meth:`route_local` first and walks
    :func:`forward_targets` on failure; each target zone's own
    ``ZoneGateway`` evaluates the forwarded invocation, so every zone's
    RNG stream and round-robin cursors stay independent — Archipelago's
    semi-autonomous per-entrypoint schedulers.
    """

    def __init__(
        self,
        watcher: Watcher,
        *,
        zone: str,
        distribution: DistributionPolicy = DistributionPolicy.DEFAULT,
        seed: Optional[int] = None,
        compiled: bool = True,
    ) -> None:
        super().__init__(
            watcher, distribution=distribution, seed=seed, compiled=compiled
        )
        self.zone = zone

    def route_local(
        self, invocation: Invocation, *, trace: bool = False
    ) -> ScheduleDecision:
        """Route with this gateway's zone as the entry zone."""
        return self.route(invocation, trace=trace, entry_zone=self.zone)

    def probe_local(self, invocation: Invocation) -> ScheduleDecision:
        """Side-effect-free traced evaluation of the zone-local pass."""
        return self.probe(invocation, entry_zone=self.zone)

    def prewarm(self, *, extra_restrictions: Sequence[str] = ()) -> int:
        """Warm indexes including this zone's entry-local restricted view."""
        return super().prewarm(
            extra_restrictions=(self.zone, *extra_restrictions)
        )


def forward_targets(
    script: Optional[TappScript],
    tag: Optional[str],
    cluster: ClusterState,
    entry_zone: str,
    zone_order: Sequence[str],
    unreachable: FrozenSet[str] = frozenset(),
) -> List[str]:
    """Ordered candidate zones for forwarding a zone-locally-failed request.

    Implements the federation reading of ``topology_tolerance``: the
    designated controller's zone is the function's *home*, and the
    tolerance bounds how far from home the invocation may run —

    * ``none``  → only the home zone (routing a request *to* its
      designated home is designated routing, not tolerance-governed
      forwarding, so the home stays reachable from any entrypoint);
    * ``same``  → only the home zone (other controllers may manage the
      scheduling there, which the engine's zone-restriction fallback
      already implements);
    * ``all``   → the home zone first, then every other zone;
    * no controller clause → no home: any zone may take the work.

    Targets are emitted in block order (designated homes first), then —
    when some block permits unrestricted forwarding — the remaining
    zones of ``zone_order`` (the federation's latency order from the
    entry zone). The entry zone itself is excluded (its pass already
    failed), as are duplicates. A ``followup: default`` tag also
    contributes the default tag's targets, since the forwarded
    evaluation re-runs the followup chain. With no script (vanilla
    fallback) every other zone is a target in latency order: the
    baseline is topology-blind, so nothing bounds the forwarding.

    ``unreachable`` names zones the entry zone cannot currently reach
    (network partition, or every worker DEAD): they are dropped from the
    emitted targets but still consume their dedup slot, so healing a
    partition restores the exact pre-partition order. A tolerance
    ``none``/``same`` function whose home zone is unreachable therefore
    gets *no* targets — the invocation fails rather than escaping its
    designated zone (the partition-tolerance invariant).
    """
    targets: List[str] = []
    seen = {entry_zone}

    def _push(zone: Optional[str]) -> None:
        if zone is not None and zone not in seen:
            seen.add(zone)
            if zone not in unreachable:
                targets.append(zone)

    if script is None or not script.tags:
        for zone in zone_order:
            _push(zone)
        return targets

    policy = script.get(tag or DEFAULT_TAG) or script.default
    if policy is None:
        return targets  # failed by policy; nothing to forward to

    unrestricted = False
    walked = set()
    while policy is not None and policy.tag not in walked:
        walked.add(policy.tag)
        for block in policy.blocks:
            clause = block.controller
            if clause is None:
                unrestricted = True
                continue
            designated = cluster.controllers.get(clause.label)
            if designated is not None:
                _push(designated.zone)
            if clause.topology_tolerance is TopologyTolerance.ALL:
                unrestricted = True
        if policy.effective_followup is FollowupKind.DEFAULT:
            policy = script.default
        else:
            policy = None
    if unrestricted:
        for zone in zone_order:
            _push(zone)
    return targets
