"""Per-zone controller bookkeeping (the paper's ConfigurableLoadBalancer).

The policy *evaluation* lives in :mod:`engine`; this module provides the
stateful controller object the runtime/simulator uses to admit, execute,
and complete invocations on workers — i.e. the part of OpenWhisk's
LoadBalancer that tracks in-flight activations per invoker.

It also exposes the hook the serving engine uses for **straggler
mitigation**: completing an admission with ``slow=True`` feeds the
watcher's load signal so tAPP ``capacity_used`` / ``overload`` conditions
steer subsequent invocations away from the slow worker.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler.state import ClusterState, WorkerState
from repro.core.scheduler.watcher import Watcher


@dataclasses.dataclass
class Admission:
    """A ticket for one invocation admitted onto a worker."""

    worker: str
    controller: str
    invocation_id: int
    # Function name for the running-function multiset (affinity signal);
    # empty string = untracked (legacy callers).
    function: str = ""


class AdmissionError(RuntimeError):
    pass


class ControllerRuntime:
    """Tracks slot occupancy for the workers a deployment exposes.

    All mutations go through the watcher so every gateway/controller view
    of load is consistent (single writer, versioned snapshots).
    """

    def __init__(self, watcher: Watcher) -> None:
        self._watcher = watcher
        self._next_id = 0

    @property
    def cluster(self) -> ClusterState:
        return self._watcher.cluster

    def admit(
        self, worker_name: str, controller_name: str, *, function: str = ""
    ) -> Admission:
        worker = self.cluster.workers.get(worker_name)
        if worker is None:
            raise AdmissionError(f"unknown worker {worker_name!r}")
        if not worker.reachable:
            raise AdmissionError(f"worker {worker_name!r} unreachable")
        self._next_id += 1
        by = dict(worker.inflight_by)
        by[controller_name] = by.get(controller_name, 0) + 1
        fields: Dict = dict(
            inflight=worker.inflight + 1,
            inflight_by=by,
            capacity_used_pct=_pct(worker.inflight + 1, worker.capacity_slots),
        )
        if function:
            running = dict(worker.running_functions)
            running[function] = running.get(function, 0) + 1
            fields["running_functions"] = running
        self._watcher.update_worker(worker_name, **fields)
        return Admission(
            worker=worker_name,
            controller=controller_name,
            invocation_id=self._next_id,
            function=function,
        )

    def admit_many(
        self, placements: Sequence[Tuple]
    ) -> List[Admission]:
        """Batch admission for ``(worker, controller[, function])`` placements.

        Issues ONE watcher update per distinct worker (instead of one per
        invocation), which is the admission-side counterpart of
        ``TappEngine.schedule_batch``; the per-worker running-function
        multiset is updated in the same write, so batch admissions leave
        state identical to the equivalent sequence of :meth:`admit` calls.
        All placements are validated before any state is mutated, so a bad
        placement leaves the cluster untouched.
        """
        normalized: List[Tuple[str, str, str]] = []
        for placement in placements:
            worker_name, controller_name = placement[0], placement[1]
            function = placement[2] if len(placement) > 2 else ""
            worker = self.cluster.workers.get(worker_name)
            if worker is None:
                raise AdmissionError(f"unknown worker {worker_name!r}")
            if not worker.reachable:
                raise AdmissionError(f"worker {worker_name!r} unreachable")
            normalized.append((worker_name, controller_name, function))

        grouped: Dict[str, List[Tuple[str, str]]] = {}
        for worker_name, controller_name, function in normalized:
            grouped.setdefault(worker_name, []).append((controller_name, function))

        for worker_name, admits in grouped.items():
            worker = self.cluster.workers[worker_name]
            by = dict(worker.inflight_by)
            running = dict(worker.running_functions)
            tracked = False
            for controller_name, function in admits:
                by[controller_name] = by.get(controller_name, 0) + 1
                if function:
                    running[function] = running.get(function, 0) + 1
                    tracked = True
            inflight = worker.inflight + len(admits)
            fields: Dict = dict(
                inflight=inflight,
                inflight_by=by,
                capacity_used_pct=_pct(inflight, worker.capacity_slots),
            )
            if tracked:
                fields["running_functions"] = running
            self._watcher.update_worker(worker_name, **fields)

        admissions: List[Admission] = []
        for worker_name, controller_name, function in normalized:
            self._next_id += 1
            admissions.append(
                Admission(
                    worker=worker_name,
                    controller=controller_name,
                    invocation_id=self._next_id,
                    function=function,
                )
            )
        return admissions

    def complete(self, admission: Admission, *, slow: bool = False) -> None:
        worker = self.cluster.workers.get(admission.worker)
        if worker is None:
            return  # worker evicted while running; nothing to release
        inflight = max(0, worker.inflight - 1)
        by = dict(worker.inflight_by)
        by[admission.controller] = max(0, by.get(admission.controller, 1) - 1)
        fields: Dict = dict(
            inflight=inflight,
            inflight_by=by,
            capacity_used_pct=_pct(inflight, worker.capacity_slots),
        )
        if admission.function:
            running = dict(worker.running_functions)
            remaining = running.get(admission.function, 1) - 1
            if remaining > 0:
                running[admission.function] = remaining
            else:
                running.pop(admission.function, None)
            fields["running_functions"] = running
        if slow:
            # Straggler signal: report the worker as fully loaded so
            # capacity_used-based policies route around it until the next
            # healthy heartbeat clears the flag.
            fields["capacity_used_pct"] = 100.0
        self._watcher.update_worker(admission.worker, **fields)

    def heartbeat(self, worker_name: str, *, healthy: bool = True) -> None:
        worker = self.cluster.workers.get(worker_name)
        if worker is None:
            return
        self._watcher.update_worker(
            worker_name,
            healthy=healthy,
            capacity_used_pct=_pct(worker.inflight, worker.capacity_slots),
        )


def _pct(inflight: int, slots: int) -> float:
    if slots <= 0:
        return 100.0
    return min(100.0, 100.0 * inflight / slots)
