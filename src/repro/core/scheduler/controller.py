"""Per-zone controller bookkeeping (the paper's ConfigurableLoadBalancer).

The policy *evaluation* lives in :mod:`engine`; this module provides the
stateful controller object the runtime/simulator uses to admit, execute,
and complete invocations on workers — i.e. the part of OpenWhisk's
LoadBalancer that tracks in-flight activations per invoker.

It also exposes the hook the serving engine uses for **straggler
mitigation**: completing an admission with ``slow=True`` feeds the
watcher's load signal so tAPP ``capacity_used`` / ``overload`` conditions
steer subsequent invocations away from the slow worker.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.core.scheduler.state import ClusterState
from repro.core.scheduler.watcher import Watcher


@dataclasses.dataclass
class Admission:
    """A ticket for one invocation admitted onto a worker."""

    worker: str
    controller: str
    invocation_id: int
    # Function name for the running-function multiset (affinity signal);
    # empty string = untracked (legacy callers).
    function: str = ""


class AdmissionError(RuntimeError):
    pass


class ControllerRuntime:
    """Tracks slot occupancy for the workers a deployment exposes.

    All mutations go through the watcher so every gateway/controller view
    of load is consistent (single writer, versioned snapshots).
    """

    def __init__(self, watcher: Watcher) -> None:
        self._watcher = watcher
        self._next_id = 0

    @property
    def cluster(self) -> ClusterState:
        return self._watcher.cluster

    def admit(
        self, worker_name: str, controller_name: str, *, function: str = ""
    ) -> Admission:
        try:
            self._watcher.record_admission(worker_name, controller_name, function)
        except KeyError:
            raise AdmissionError(f"unknown worker {worker_name!r}") from None
        except ValueError:
            raise AdmissionError(f"worker {worker_name!r} unreachable") from None
        self._next_id += 1
        return Admission(
            worker=worker_name,
            controller=controller_name,
            invocation_id=self._next_id,
            function=function,
        )

    def admit_many(
        self, placements: Sequence[Tuple]
    ) -> List[Admission]:
        """Batch admission for ``(worker, controller[, function])`` placements.

        The admission-side counterpart of ``TappEngine.schedule_batch``:
        every placement is validated before any state is mutated, so a bad
        placement leaves the cluster untouched, and the recorded state is
        identical to the equivalent sequence of :meth:`admit` calls.
        """
        normalized: List[Tuple[str, str, str]] = []
        for placement in placements:
            worker_name, controller_name = placement[0], placement[1]
            function = placement[2] if len(placement) > 2 else ""
            worker = self.cluster.workers.get(worker_name)
            if worker is None:
                raise AdmissionError(f"unknown worker {worker_name!r}")
            if not worker.reachable:
                raise AdmissionError(f"worker {worker_name!r} unreachable")
            normalized.append((worker_name, controller_name, function))

        admissions: List[Admission] = []
        for worker_name, controller_name, function in normalized:
            self._next_id += 1
            self._watcher.record_admission(
                worker_name, controller_name, function
            )
            admissions.append(
                Admission(
                    worker=worker_name,
                    controller=controller_name,
                    invocation_id=self._next_id,
                    function=function,
                )
            )
        return admissions

    def complete(self, admission: Admission, *, slow: bool = False) -> None:
        self._watcher.record_completion(
            admission.worker,
            admission.controller,
            admission.function,
            slow=slow,
        )

    def heartbeat(self, worker_name: str, *, healthy: bool = True) -> None:
        worker = self.cluster.workers.get(worker_name)
        if worker is None:
            return
        self._watcher.update_worker(
            worker_name,
            healthy=healthy,
            capacity_used_pct=_pct(worker.inflight, worker.capacity_slots),
        )


def _pct(inflight: int, slots: int) -> float:
    if slots <= 0:
        return 100.0
    return min(100.0, 100.0 * inflight / slots)
