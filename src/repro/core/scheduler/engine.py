"""The tAPP policy-evaluation engine (paper §3.3 semantics).

Given an invocation (function name + tag), a parsed :class:`TappScript`,
and a cluster snapshot, the engine produces a :class:`ScheduleDecision`:
either a (controller, worker) placement or a followup outcome, together
with an optional full evaluation trace (used by tests, the simulator, and
serving observability).

Evaluation order, faithful to the paper:

1. Resolve the tag (untagged → ``default``; unknown tag → ``default``;
   no script at all → the caller falls back to the vanilla scheduler).
2. Order the tag's blocks by the tag-level strategy (default best_first).
3. Per block: resolve the executing controller (the gateway step):
   the named controller if available, otherwise per ``topology_tolerance``
   (all → any available controller; same → any available controller but
   workers restricted to the designated controller's zone; none → block
   invalid). Blocks without a controller clause are executed by a
   gateway-chosen controller (round-robin cursor).
4. Per block: expand worker items against the controller's distribution
   view, order candidates by block/set strategy, and pick the first one
   whose resolved constraint set (invalidate condition + affinity /
   anti-affinity clauses; see :mod:`repro.core.scheduler.constraints`)
   does not invalidate it.
5. All blocks exhausted → followup (``fail`` | re-evaluate ``default``;
   the default tag's own followup is always ``fail``).

Two execution paths implement these semantics:

* the **interpreter** (``TappEngine(compiled=False)``) — the original
  reference implementation, which re-derives script facts and rebuilds
  distribution views on every call;
* the **compiled fast path** (default) — evaluates a pre-lowered
  :class:`~repro.core.tapp.compile.CompiledScript` against epoch-cached
  topology views (:func:`~repro.core.scheduler.topology.cached_view_entry`),
  with tracing fully elided unless ``trace=True``.

Both paths produce bit-identical placements and traces under a fixed
seed; ``tests/test_scheduler_compile.py`` property-tests this over
randomized scripts and clusters. Tracing defaults to **off**: the sim and
serving hot loops pay nothing for :class:`TraceEvent` construction, while
tests and observability pass ``trace=True`` and get the identical trace.

**Entry zones (federation, PR 5).** ``schedule(..., entry_zone=Z)``
evaluates the policy as zone ``Z``'s semi-autonomous scheduler sees it:
controller-less blocks round-robin only over ``Z``'s controllers with
workers restricted to ``Z``. Designated-controller blocks depend on the
clause's ``topology_tolerance``: ``none``/``same`` pin candidates to
the designated controller's home zone (routing *to* the home is the
script's explicit intent and always allowed; executing outside it never
is), while ``all`` evaluates under the entry restriction like any other
block — the federation's forwarding walk covers the rest of the
cluster. Block-level restrictions (the pin, or the tolerance fallback
zone) take precedence over the entry restriction. With
``entry_zone=None`` (the default) evaluation is exactly the flat
single-entry behaviour of PR 1–4; both execution paths consume identical
RNG draws and emit identical traces either way.
"""
from __future__ import annotations

import dataclasses
import enum
import random as _random
from typing import (
    TYPE_CHECKING,
    Callable,
    Dict,
    List,
    Optional,
    Sequence,
    Tuple,
)

from repro.core.scheduler.constraints import (
    constraint_reason,
    resolve_constraints,
)
from repro.core.scheduler.state import ClusterState, ControllerState, WorkerState
from repro.core.scheduler.strategy import (
    coprime_order_cached,
    iter_ordered,
    iter_random,
    stable_hash,
)
from repro.core.scheduler.topology import (
    DistributionPolicy,
    ItemIndex,
    WorkerView,
    cached_view_entry,
    distribution_view,
)
from repro.core.tapp.ast import (
    DEFAULT_TAG,
    Block,
    FollowupKind,
    Strategy,
    TagPolicy,
    TappScript,
    TopologyTolerance,
    WorkerRef,
    WorkerSet,
)
if TYPE_CHECKING:  # imported lazily at runtime (in compiled_plan):
    # tapp.compile lowers through the scheduler-side constraint layer, so
    # keeping this edge out of import time leaves tapp ↔ scheduler free of
    # module-scope cycles in either load order.
    from repro.core.tapp.compile import (
        CompiledBlock,
        CompiledScript,
        CompiledTag,
    )


class Outcome(enum.Enum):
    SCHEDULED = "scheduled"
    FAILED = "failed"


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    kind: str  # "block", "controller", "candidate", "followup", "tag"
    detail: str


@dataclasses.dataclass
class ScheduleDecision:
    outcome: Outcome
    worker: Optional[str] = None
    controller: Optional[str] = None
    tag: Optional[str] = None
    used_default_fallback: bool = False
    # The zone constraint of the block that actually scheduled (None when
    # unrestricted); on failure, the constraint of the last block evaluated.
    zone_restriction: Optional[str] = None
    # True iff a tAPP policy evaluated and explicitly failed the request
    # (followup: fail exhausted, or no usable default tag). Structured
    # replacement for sniffing the trace, which is empty on the hot path.
    failed_by_policy: bool = False
    trace: List[TraceEvent] = dataclasses.field(default_factory=list)

    @property
    def scheduled(self) -> bool:
        return self.outcome is Outcome.SCHEDULED

    def explain(self) -> str:
        return "\n".join(f"{e.kind:>10}: {e.detail}" for e in self.trace)


@dataclasses.dataclass(frozen=True)
class Invocation:
    """One function-execution request."""

    function: str
    tag: Optional[str] = None
    # Data-plane context: which model / resource the function touches.
    model_id: Optional[str] = None
    request_id: int = 0
    # Stable function hash, computed once at construction (it is read
    # several times per decision — block ordering, co-prime primaries —
    # and a per-access blake2b would dominate the indexed fast path).
    hash: int = dataclasses.field(init=False, repr=False, compare=False)

    def __post_init__(self) -> None:
        object.__setattr__(self, "hash", stable_hash(self.function))


# Optional per-decision callback for batch scheduling: invoked immediately
# after each decision, before the next invocation is evaluated, so callers
# can interleave admissions and keep results identical to sequential calls.
OnDecision = Callable[[Invocation, ScheduleDecision], None]


# -- warm-first orderings (stable partitions, zero RNG draws) ---------------
#
# The warm-pool lifecycle (platform/lifecycle.py) maintains
# WorkerState.warm_idle; with no lifecycle armed every count is 0, every
# partition is the identity, and warm-first degenerates to best_first
# exactly — which is what keeps the unconfigured path bit-identical.


def _warm_view_order(views, fhash: int):
    """One tier's views, warm candidates first (stable within each half)."""
    warm = [v for v in views if v.worker.warm_idle.get(fhash, 0) > 0]
    if not warm:
        return views
    warm.extend(v for v in views if v.worker.warm_idle.get(fhash, 0) <= 0)
    return warm


def _warm_worker_order(workers, fhash: int):
    """One tier's workers, warm first (interpreter set expansion)."""
    warm = [w for w in workers if w.warm_idle.get(fhash, 0) > 0]
    if not warm:
        return workers
    warm.extend(w for w in workers if w.warm_idle.get(fhash, 0) <= 0)
    return warm


def _warm_item_order(items, by_name, fhash: int):
    """A wrk item list, items whose worker is warm first (ghost or
    out-of-view labels count as cold)."""
    warm, cold = [], []
    for item in items:
        view = by_name.get(item.label)
        if view is not None and view.worker.warm_idle.get(fhash, 0) > 0:
            warm.append(item)
        else:
            cold.append(item)
    warm.extend(cold)
    return warm


def _warm_set_order(items, entry, fhash: int):
    """Set items with any warm member first (compiled traced path)."""
    warm, cold = [], []
    for item in items:
        local, foreign = entry.set_members(item.label)
        if any(
            v.worker.warm_idle.get(fhash, 0) > 0 for v in local
        ) or any(v.worker.warm_idle.get(fhash, 0) > 0 for v in foreign):
            warm.append(item)
        else:
            cold.append(item)
    warm.extend(cold)
    return warm


def _interp_warm_set_order(items, views, fhash: int):
    """Set items with any warm member first (interpreter path)."""
    warm, cold = [], []
    for item in items:
        if any(
            v.worker.in_set(item.label)
            and v.worker.warm_idle.get(fhash, 0) > 0
            for v in views
        ):
            warm.append(item)
        else:
            cold.append(item)
    warm.extend(cold)
    return warm


class TappEngine:
    """Stateless policy evaluator (all mutable state lives in the cluster
    snapshot and in the RNG/cursors the caller owns)."""

    def __init__(
        self,
        distribution: DistributionPolicy = DistributionPolicy.DEFAULT,
        *,
        seed: Optional[int] = None,
        compiled: bool = True,
        batch_backend: Optional[str] = None,
    ) -> None:
        self.distribution = distribution
        self.compiled = compiled
        self._rng = _random.Random(seed)
        self._controller_cursor = 0  # round-robin for controller-less blocks
        self._plan: Optional[CompiledScript] = None
        self._plan_source: Optional[TappScript] = None
        # Mask-plane batch routing (scheduler/batch.py): which kernel
        # backend resolves the stacked order planes. None → the
        # REPRO_BATCH_BACKEND env var, then "numpy".
        if batch_backend is None:
            import os

            batch_backend = os.environ.get("REPRO_BATCH_BACKEND") or "numpy"
        self._batch_backend = batch_backend
        self._batch_router = None

    # -- public API ----------------------------------------------------------

    def schedule(
        self,
        invocation: Invocation,
        script: Optional[TappScript],
        cluster: ClusterState,
        *,
        trace: bool = False,
        entry_zone: Optional[str] = None,
    ) -> ScheduleDecision:
        """Resolve one invocation to a worker placement.

        ``entry_zone`` evaluates the policy zone-locally (see the module
        docstring): ``None`` keeps the flat single-entry semantics.
        """
        if self.compiled:
            return self._schedule_compiled(
                invocation, script, cluster, trace, entry_zone
            )
        return self._schedule_interpreted(
            invocation, script, cluster, trace, entry_zone
        )

    def schedule_batch(
        self,
        invocations: Sequence[Invocation],
        script: Optional[TappScript],
        cluster: ClusterState,
        *,
        trace: bool = False,
        entry_zone: Optional[str] = None,
        on_decision: Optional[OnDecision] = None,
    ) -> List[ScheduleDecision]:
        """Resolve a batch of invocations against one cluster snapshot.

        The compiled plan and the epoch-cached topology views are shared
        across the whole batch; decisions are evaluated in order, with
        ``on_decision`` fired after each one so the caller can admit the
        placement before the next decision is made — which keeps batch
        results bit-identical to a sequence of :meth:`schedule` calls with
        interleaved admissions.

        Untraced compiled batches of two or more invocations route
        through the vectorized mask-plane path
        (:class:`~repro.core.scheduler.batch.BatchRouter`): items whose
        cascade consumes no RNG draws are resolved against stacked
        order/availability planes with memoized outcomes, the rest fall
        back to per-item :meth:`schedule` calls — placements, traces,
        RNG streams, and cursor movement are bit-identical either way.
        """
        if self.compiled and script is not None and script.tags:
            plan = self.compiled_plan(script)  # hoist out of the loop
            if not trace and len(invocations) >= 2:
                router = self._batch_router
                if router is None:
                    from repro.core.scheduler.batch import BatchRouter

                    router = self._batch_router = BatchRouter(
                        self, backend=self._batch_backend
                    )
                return router.route_batch(
                    invocations, script, plan, cluster, entry_zone,
                    on_decision,
                )
        decisions: List[ScheduleDecision] = []
        for invocation in invocations:
            decision = self.schedule(
                invocation, script, cluster, trace=trace,
                entry_zone=entry_zone,
            )
            if on_decision is not None:
                on_decision(invocation, decision)
            decisions.append(decision)
        return decisions

    def scheduling_state(self):
        """Snapshot the mutable decision state (RNG stream + controller
        cursor) so a probe/what-if evaluation can be rolled back."""
        return self._rng.getstate(), self._controller_cursor

    def restore_scheduling_state(self, state) -> None:
        rng_state, cursor = state
        self._rng.setstate(rng_state)
        self._controller_cursor = cursor

    def compiled_plan(self, script: TappScript) -> "CompiledScript":
        """The lowered plan for ``script``, compiled once per script object."""
        if script is not self._plan_source:
            from repro.core.tapp.compile import compile_script

            self._plan = compile_script(script)
            self._plan_source = script
        assert self._plan is not None
        return self._plan

    def adopt_plan(self, script: TappScript, plan: "CompiledScript") -> None:
        """Pre-seed the plan cache with an externally-compiled plan.

        The platform's policy apply compiles the script once as its
        lowering check; adopting that plan here means the first decision
        after the swap does not recompile. The caller guarantees ``plan``
        was lowered from the same tag content as ``script`` (the watcher's
        published script shares the source script's ``tags`` tuple).
        """
        self._plan = plan
        self._plan_source = script

    # ======================================================================
    # Compiled fast path
    # ======================================================================

    def _schedule_compiled(
        self,
        invocation: Invocation,
        script: Optional[TappScript],
        cluster: ClusterState,
        trace: bool,
        entry_zone: Optional[str] = None,
    ) -> ScheduleDecision:
        decision = ScheduleDecision(outcome=Outcome.FAILED)
        tr = decision.trace if trace else None
        if script is None or not script.tags:
            if tr is not None:
                tr.append(
                    TraceEvent(
                        "tag", "no tAPP script: caller should use vanilla fallback"
                    )
                )
            return decision

        plan = self.compiled_plan(script)
        tag_name = invocation.tag or DEFAULT_TAG
        ctag = plan.tags.get(tag_name)
        if ctag is None:
            if tr is not None:
                tr.append(
                    TraceEvent(
                        "tag",
                        f"tag {tag_name!r} not in script; falling back to "
                        f"{DEFAULT_TAG!r}",
                    )
                )
            ctag = plan.default
            if ctag is None:
                if tr is not None:
                    tr.append(
                        TraceEvent("tag", "no default tag either: fail")
                    )
                decision.failed_by_policy = True
                return decision

        return self._c_tag(
            invocation, ctag, plan, cluster, decision, tr,
            is_fallback=False, zone_override=entry_zone,
            entry_zone=entry_zone,
        )

    def _c_tag(
        self,
        invocation: Invocation,
        ctag: CompiledTag,
        plan: CompiledScript,
        cluster: ClusterState,
        decision: ScheduleDecision,
        tr: Optional[List[TraceEvent]],
        *,
        is_fallback: bool,
        zone_override: Optional[str],
        entry_zone: Optional[str] = None,
    ) -> ScheduleDecision:
        decision.tag = ctag.tag
        decision.used_default_fallback = is_fallback
        if tr is not None:
            tr.append(
                TraceEvent(
                    "tag",
                    f"evaluating tag {ctag.tag!r} "
                    f"(strategy={ctag.strategy.value}, "
                    f"followup={ctag.followup.value})",
                )
            )

        for block_index, cblock in self._c_ordered(
            ctag.enumerated, ctag.strategy, invocation.hash
        ):
            placed = self._c_block(
                invocation, cblock, block_index, cluster, decision, tr,
                zone_override, entry_zone,
            )
            if placed is not None:
                controller, worker = placed
                decision.outcome = Outcome.SCHEDULED
                decision.controller = controller
                decision.worker = worker
                return decision

        # All blocks exhausted → followup.
        if tr is not None:
            tr.append(
                TraceEvent(
                    "followup",
                    f"tag {ctag.tag!r} exhausted → {ctag.followup.value}",
                )
            )
        if ctag.followup is FollowupKind.DEFAULT and not is_fallback:
            # Paper §3.4: `topology_tolerance: same` pins the default-tag
            # fallback to the designated controller's zone. The label table
            # is precompiled; only the live zone lookup happens here.
            sticky_zone = zone_override
            for label in ctag.sticky_same_labels:
                designated = cluster.controllers.get(label)
                if designated is not None:
                    sticky_zone = designated.zone
                    if tr is not None:
                        tr.append(
                            TraceEvent(
                                "followup",
                                f"tolerance=same → default restricted to "
                                f"zone {sticky_zone!r}",
                            )
                        )
                    break
            default_tag = plan.default
            if default_tag is not None and default_tag.tag != ctag.tag:
                return self._c_tag(
                    invocation, default_tag, plan, cluster, decision, tr,
                    is_fallback=True, zone_override=sticky_zone,
                    entry_zone=entry_zone,
                )
            if tr is not None:
                tr.append(
                    TraceEvent("followup", "no usable default tag: fail")
                )
            decision.failed_by_policy = True
        else:
            decision.failed_by_policy = True
        decision.outcome = Outcome.FAILED
        return decision

    def _c_block(
        self,
        invocation: Invocation,
        cblock: CompiledBlock,
        block_index: int,
        cluster: ClusterState,
        decision: ScheduleDecision,
        tr: Optional[List[TraceEvent]],
        zone_override: Optional[str],
        entry_zone: Optional[str] = None,
    ) -> Optional[Tuple[str, str]]:
        if cblock.controller is None:
            # No controller clause: the gateway tries the available
            # controllers starting at the round-robin cursor (§5.4.1).
            # With an entry zone, only that zone's controllers take part
            # (the per-zone gateway hands work to its own zone first).
            if entry_zone is None:
                controllers = [
                    c for c in cluster.controllers.values() if c.available
                ]
            else:
                controllers = [
                    c for c in cluster.controllers.values()
                    if c.available and c.zone == entry_zone
                ]
            if not controllers:
                if tr is not None:
                    tr.append(
                        TraceEvent(
                            "controller",
                            f"block[{block_index}]: no available controller",
                        )
                    )
                return None
            start = self._controller_cursor
            self._controller_cursor += 1
            n = len(controllers)
            for offset in range(n):
                controller = controllers[(start + offset) % n]
                if tr is not None:
                    tr.append(
                        TraceEvent(
                            "controller",
                            f"block[{block_index}]: gateway → {controller.name!r}",
                        )
                    )
                placed = self._c_block_on(
                    invocation, cblock, controller, zone_override, cluster, tr
                )
                if placed is not None:
                    decision.zone_restriction = zone_override
                    return placed
            return None

        controller, zone_restriction = self._c_resolve_controller(
            cblock, block_index, cluster, tr, entry_zone
        )
        if controller is None:
            return None
        effective = zone_restriction or zone_override
        decision.zone_restriction = effective
        return self._c_block_on(
            invocation, cblock, controller, effective, cluster, tr
        )

    def _c_resolve_controller(
        self,
        cblock: CompiledBlock,
        block_index: int,
        cluster: ClusterState,
        tr: Optional[List[TraceEvent]],
        entry_zone: Optional[str] = None,
    ) -> Tuple[Optional[ControllerState], Optional[str]]:
        clause = cblock.controller
        assert clause is not None

        def note(text: str) -> None:
            if tr is not None:
                tr.append(
                    TraceEvent("controller", f"block[{block_index}]: {text}")
                )

        tol = clause.topology_tolerance
        designated = cluster.controllers.get(clause.label)
        if designated is not None and designated.available:
            # Entry-zone (federated) evaluation: tolerance none/same means
            # the work must *execute* in the designated controller's home
            # zone, so the block's candidates are pinned to it — the
            # guarantee "tolerance none never places outside its zone"
            # must hold no matter which zone the request entered at.
            # Flat evaluation (entry_zone=None) keeps the paper's §3.3
            # semantics, where tolerance only matters when the designated
            # controller is unavailable.
            if entry_zone is not None and tol is not TopologyTolerance.ALL:
                note(
                    f"designated controller {clause.label!r} available "
                    f"(tolerance={tol.value} → workers pinned to zone "
                    f"{designated.zone!r})"
                )
                return designated, designated.zone
            note(f"designated controller {clause.label!r} available")
            return designated, None

        designated_zone = designated.zone if designated is not None else None
        if tol is TopologyTolerance.NONE:
            note(
                f"controller {clause.label!r} unavailable, tolerance=none → "
                f"block invalid"
            )
            return None, None
        alternative = self._round_robin_controller(cluster)
        if alternative is None:
            note("no alternative controller available")
            return None, None
        if tol is TopologyTolerance.SAME:
            if designated_zone is None:
                note(
                    f"controller {clause.label!r} unknown and tolerance=same → "
                    f"cannot resolve its zone, block invalid"
                )
                return None, None
            note(
                f"controller {clause.label!r} unavailable, tolerance=same → "
                f"{alternative.name!r} restricted to zone {designated_zone!r}"
            )
            return alternative, designated_zone
        note(
            f"controller {clause.label!r} unavailable, tolerance=all → "
            f"{alternative.name!r}"
        )
        return alternative, None

    def _c_block_on(
        self,
        invocation: Invocation,
        cblock: CompiledBlock,
        controller: ControllerState,
        zone_restriction: Optional[str],
        cluster: ClusterState,
        tr: Optional[List[TraceEvent]],
    ) -> Optional[Tuple[str, str]]:
        entry = cached_view_entry(
            cluster,
            controller.zone,
            self.distribution,
            controller_name=controller.name,
            zone_restriction=zone_restriction,
        )
        fhash = invocation.hash
        if tr is None:
            # Indexed fast path: epoch-compiled candidate orders + the
            # incrementally-maintained availability bitmask. Produces the
            # same placement (and consumes the same RNG draws) as the
            # traced per-candidate walk below.
            return self._c_block_indexed(cblock, controller, entry, cluster,
                                         fhash)

        if not cblock.uses_sets:
            by_name = entry.by_name
            if cblock.strategy is Strategy.WARM_FIRST:
                items = _warm_item_order(cblock.wrks, by_name, fhash)
            else:
                items = self._c_ordered(cblock.wrks, cblock.strategy, fhash)
            for item in items:
                view = by_name.get(item.label)
                if view is None:
                    # Unknown label or filtered out by the zone restriction
                    # ⇒ outside this controller's distribution view.
                    tr.append(
                        TraceEvent(
                            "candidate",
                            f"{item.label}: outside controller "
                            f"{controller.name!r}'s distribution view",
                        )
                    )
                    continue
                placed = self._c_try(item, view, controller, tr)
                if placed is not None:
                    return placed
            return None

        # Set list: block-level strategy orders the *set items*; each set's
        # inner strategy orders its members, local tier first. Member lists
        # come from the epoch-cached per-set expansion. Random tiers are
        # drawn lazily (iter_random), so RNG consumption stops at the
        # first valid candidate on every path.
        if cblock.strategy is Strategy.WARM_FIRST:
            set_items = _warm_set_order(cblock.sets, entry, fhash)
        else:
            set_items = self._c_ordered(cblock.sets, cblock.strategy, fhash)
        for item in set_items:
            local, foreign = entry.set_members(item.label)
            inner = item.strategy
            if inner is Strategy.RANDOM:
                groups: Tuple[Sequence[WorkerView], ...] = (
                    iter_random(local, self._rng),
                    iter_random(foreign, self._rng),
                )
            elif inner is Strategy.PLATFORM:
                groups = (
                    [local[i] for i in coprime_order_cached(len(local), fhash)],
                    [foreign[i] for i in coprime_order_cached(len(foreign), fhash)],
                )
            elif inner is Strategy.WARM_FIRST:
                # Warm partition within each tier; zero RNG draws.
                groups = (
                    _warm_view_order(local, fhash),
                    _warm_view_order(foreign, fhash),
                )
            else:  # BEST_FIRST: view order (local-first, insertion order)
                groups = (local, foreign)
            for group in groups:
                for view in group:
                    placed = self._c_try(item, view, controller, tr)
                    if placed is not None:
                        return placed
        return None

    def _c_block_indexed(
        self,
        cblock: CompiledBlock,
        controller: ControllerState,
        entry,
        cluster: ClusterState,
        fhash: int,
    ) -> Optional[Tuple[str, str]]:
        """Evaluate one block against its candidate index (no tracing).

        Every epoch-static fact — candidate membership, static constraint
        halves, strategy orders — was materialized when the index was
        built; the only per-decision work is syncing the availability
        bitmask with the ledger's load log (O(1) per admission/completion)
        and taking the first available position in precomputed order.
        """
        bindex = entry.block_index(cblock)
        if not cblock.uses_sets:
            idx = bindex.wrk
            pos = self._c_pick(idx, cblock.strategy, fhash, cluster)
            if pos is None:
                return None
            return controller.name, idx.workers[pos].name

        sets = cblock.sets
        n_items = len(sets)
        strategy = cblock.strategy
        indexes = bindex.sets
        if strategy is Strategy.BEST_FIRST or n_items <= 1:
            item_order: Sequence[int] = range(n_items)
        elif strategy is Strategy.PLATFORM:
            item_order = coprime_order_cached(n_items, fhash)
        elif strategy is Strategy.WARM_FIRST:
            # Stable partition: set items with any warm member first.
            item_order = sorted(
                range(n_items),
                key=lambda i: not indexes[i].has_warm(cluster, fhash),
            )
        else:  # RANDOM: same lazy draw sequence as ordering the items
            item_order = iter_random(range(n_items), self._rng)
        for ipos in item_order:
            pos = self._c_pick(indexes[ipos], sets[ipos].strategy, fhash,
                               cluster)
            if pos is not None:
                idx = indexes[ipos]
                return controller.name, idx.workers[pos].name
        return None

    def _c_pick(
        self,
        idx: ItemIndex,
        strategy: Strategy,
        fhash: int,
        cluster: ClusterState,
    ) -> Optional[int]:
        """First available candidate position under ``strategy``."""
        avail = idx.refresh(cluster)
        if strategy is Strategy.RANDOM:
            # Draws through the tiers even when nothing is available —
            # the reference paths consume those draws too.
            return idx.pick_random(avail, self._rng)
        if not avail:
            return None  # e.g. fully saturated: O(1), no rescan
        if strategy is Strategy.PLATFORM:
            return idx.pick_platform(avail, fhash)
        if strategy is Strategy.WARM_FIRST:
            # Warm partition per tier: warm locals, cold locals, warm
            # foreigns, cold foreigns — pure bit ops, zero RNG draws.
            # With no lifecycle armed the warm mask is 0 and this is
            # exactly the BEST_FIRST lowest-bit pick.
            warm = idx.warm_mask(cluster, fhash) & avail
            if warm:
                local = idx.local_mask
                wl = warm & local
                if wl:
                    return (wl & -wl).bit_length() - 1
                al = avail & local
                if al:
                    return (al & -al).bit_length() - 1
                return (warm & -warm).bit_length() - 1
        return (avail & -avail).bit_length() - 1  # BEST_FIRST: lowest bit

    def _c_try(
        self,
        item,  # CompiledWrk | CompiledSet
        view: WorkerView,
        controller: ControllerState,
        tr: Optional[List[TraceEvent]],
    ) -> Optional[Tuple[str, str]]:
        """Check one candidate; fast path does no string work at all."""
        worker = view.worker
        if tr is None:
            if item.invalid(worker) or view.saturated:
                return None
            return controller.name, worker.name
        reason = constraint_reason(worker, item.spec)
        if reason is None and view.saturated:
            reason = (
                f"controller entitlement saturated "
                f"({worker.inflight}/{view.slot_cap} slots)"
            )
        if reason is None:
            tr.append(
                TraceEvent(
                    "candidate",
                    f"{worker.name}: VALID (zone={worker.zone}, "
                    f"inflight={worker.inflight}/{worker.capacity_slots})",
                )
            )
            return controller.name, worker.name
        tr.append(
            TraceEvent("candidate", f"{worker.name}: invalid — {reason}")
        )
        return None

    def _c_ordered(self, items: Sequence, strategy: Strategy, fhash: int):
        """Order pre-compiled items; mirrors iter_ordered draw-for-draw.

        Random orderings are lazy (one draw per item actually tried), so
        the traced path, the interpreter, and the indexed fast path all
        consume identical RNG streams no matter where evaluation stops.
        """
        if strategy is Strategy.BEST_FIRST or not items:
            return items
        if strategy is Strategy.PLATFORM:
            order = coprime_order_cached(len(items), fhash)
            return (items[i] for i in order)
        if strategy is Strategy.WARM_FIRST:
            # Only reachable at tag level (blocks have no single warmth);
            # the validator rejects it there, so treat defensively as
            # best_first. Block/set warm-first is handled at call sites.
            return items
        return iter_random(items, self._rng)

    # ======================================================================
    # Interpreter (reference path; `TappEngine(compiled=False)`)
    # ======================================================================

    def _schedule_interpreted(
        self,
        invocation: Invocation,
        script: Optional[TappScript],
        cluster: ClusterState,
        trace: bool,
        entry_zone: Optional[str] = None,
    ) -> ScheduleDecision:
        decision = ScheduleDecision(outcome=Outcome.FAILED)
        tr = decision.trace if trace else None
        if script is None or not script.tags:
            if tr is not None:
                tr.append(
                    TraceEvent(
                        "tag", "no tAPP script: caller should use vanilla fallback"
                    )
                )
            return decision

        tag_name = invocation.tag or DEFAULT_TAG
        policy = script.get(tag_name)
        if policy is None:
            if tr is not None:
                tr.append(
                    TraceEvent(
                        "tag",
                        f"tag {tag_name!r} not in script; falling back to "
                        f"{DEFAULT_TAG!r}",
                    )
                )
            policy = script.default
            tag_name = DEFAULT_TAG
            if policy is None:
                if tr is not None:
                    tr.append(
                        TraceEvent("tag", "no default tag either: fail")
                    )
                decision.failed_by_policy = True
                return decision

        return self._evaluate_tag(
            invocation, policy, script, cluster, decision, tr,
            zone_override=entry_zone, entry_zone=entry_zone,
        )

    # -- tag evaluation -------------------------------------------------------

    def _evaluate_tag(
        self,
        invocation: Invocation,
        policy: TagPolicy,
        script: TappScript,
        cluster: ClusterState,
        decision: ScheduleDecision,
        tr: Optional[List[TraceEvent]],
        *,
        is_fallback: bool = False,
        zone_override: Optional[str] = None,
        entry_zone: Optional[str] = None,
    ) -> ScheduleDecision:
        decision.tag = policy.tag
        decision.used_default_fallback = is_fallback
        if tr is not None:
            tr.append(
                TraceEvent(
                    "tag",
                    f"evaluating tag {policy.tag!r} "
                    f"(strategy={policy.effective_strategy.value}, "
                    f"followup={policy.effective_followup.value})",
                )
            )

        blocks = iter_ordered(
            list(enumerate(policy.blocks)),
            policy.effective_strategy,
            rng=self._rng,
            function_hash=invocation.hash,
        )
        for block_index, block in blocks:
            placed = self._evaluate_block(
                invocation, block, block_index, cluster, decision, tr,
                zone_override=zone_override, entry_zone=entry_zone,
            )
            if placed is not None:
                controller, worker = placed
                decision.outcome = Outcome.SCHEDULED
                decision.controller = controller
                decision.worker = worker
                return decision

        # All blocks exhausted → followup.
        followup = policy.effective_followup
        if tr is not None:
            tr.append(
                TraceEvent(
                    "followup", f"tag {policy.tag!r} exhausted → {followup.value}"
                )
            )
        if followup is FollowupKind.DEFAULT and not is_fallback:
            # Paper §3.4 (followup × topology_tolerance interaction): when a
            # tag with `topology_tolerance: same` falls back to the default
            # tag, other controllers may manage the scheduling BUT execution
            # stays restricted to the designated controller's zone.
            sticky_zone = zone_override
            for block in policy.blocks:
                if (
                    block.controller is not None
                    and block.controller.topology_tolerance
                    is TopologyTolerance.SAME
                ):
                    designated = cluster.controllers.get(block.controller.label)
                    if designated is not None:
                        sticky_zone = designated.zone
                        if tr is not None:
                            tr.append(
                                TraceEvent(
                                    "followup",
                                    f"tolerance=same → default restricted to "
                                    f"zone {sticky_zone!r}",
                                )
                            )
                        break
            default_policy = script.default
            if default_policy is not None and default_policy.tag != policy.tag:
                return self._evaluate_tag(
                    invocation,
                    default_policy,
                    script,
                    cluster,
                    decision,
                    tr,
                    is_fallback=True,
                    zone_override=sticky_zone,
                    entry_zone=entry_zone,
                )
            if tr is not None:
                tr.append(
                    TraceEvent("followup", "no usable default tag: fail")
                )
            decision.failed_by_policy = True
        else:
            decision.failed_by_policy = True
        decision.outcome = Outcome.FAILED
        return decision

    # -- block evaluation ------------------------------------------------------

    def _evaluate_block(
        self,
        invocation: Invocation,
        block: Block,
        block_index: int,
        cluster: ClusterState,
        decision: ScheduleDecision,
        tr: Optional[List[TraceEvent]],
        *,
        zone_override: Optional[str] = None,
        entry_zone: Optional[str] = None,
    ) -> Optional[Tuple[str, str]]:
        if block.controller is None:
            # No controller clause: the gateway tries the available
            # controllers starting at the round-robin cursor. If one
            # controller's view has no valid worker, control returns to the
            # gateway, which passes the invocation to the next controller
            # (paper §5.4.1: the isolated policy "returns control to Nginx,
            # which passes the invocation to a different controller").
            # With an entry zone, only that zone's controllers take part
            # (mirrors the compiled path exactly — same lists, same cursor
            # arithmetic, same RNG consumption).
            if entry_zone is None:
                controllers = [
                    c for c in cluster.controllers.values() if c.available
                ]
            else:
                controllers = [
                    c for c in cluster.controllers.values()
                    if c.available and c.zone == entry_zone
                ]
            if not controllers:
                if tr is not None:
                    tr.append(
                        TraceEvent(
                            "controller",
                            f"block[{block_index}]: no available controller",
                        )
                    )
                return None
            start = self._controller_cursor
            self._controller_cursor += 1
            for offset in range(len(controllers)):
                controller = controllers[(start + offset) % len(controllers)]
                if tr is not None:
                    tr.append(
                        TraceEvent(
                            "controller",
                            f"block[{block_index}]: gateway → {controller.name!r}",
                        )
                    )
                placed = self._evaluate_block_on(
                    invocation, block, controller, zone_override, cluster, tr
                )
                if placed is not None:
                    # The scheduling block ran unrestricted (modulo any
                    # followup sticky zone) — record *its* constraint, not a
                    # stale value from an earlier failed block.
                    decision.zone_restriction = zone_override
                    return placed
            return None

        controller, zone_restriction, note = self._resolve_controller(
            block, cluster, entry_zone
        )
        if tr is not None:
            tr.append(TraceEvent("controller", f"block[{block_index}]: {note}"))
        if controller is None:
            return None
        zone_restriction = zone_restriction or zone_override
        decision.zone_restriction = zone_restriction
        return self._evaluate_block_on(
            invocation, block, controller, zone_restriction, cluster, tr
        )

    def _evaluate_block_on(
        self,
        invocation: Invocation,
        block: Block,
        controller: ControllerState,
        zone_restriction: Optional[str],
        cluster: ClusterState,
        tr: Optional[List[TraceEvent]],
    ) -> Optional[Tuple[str, str]]:
        views = distribution_view(
            cluster,
            controller.zone,
            self.distribution,
            controller_name=controller.name,
            zone_restriction=zone_restriction,
        )
        view_map: Dict[str, WorkerView] = {v.worker.name: v for v in views}

        candidates = self._expand_block_candidates(
            invocation, block, views, view_map
        )
        for worker, spec in candidates:
            view = view_map.get(worker.name)
            if view is None:
                if tr is not None:
                    tr.append(
                        TraceEvent(
                            "candidate",
                            f"{worker.name}: outside controller "
                            f"{controller.name!r}'s distribution view",
                        )
                    )
                continue
            reason = constraint_reason(worker, spec)
            if reason is None and view.saturated:
                reason = (
                    f"controller entitlement saturated "
                    f"({worker.inflight}/{view.slot_cap} slots)"
                )
            if reason is None:
                if tr is not None:
                    tr.append(
                        TraceEvent(
                            "candidate",
                            f"{worker.name}: VALID (zone={worker.zone}, "
                            f"inflight={worker.inflight}/{worker.capacity_slots})",
                        )
                    )
                return controller.name, worker.name
            if tr is not None:
                tr.append(
                    TraceEvent("candidate", f"{worker.name}: invalid — {reason}")
                )
        return None

    def _resolve_controller(
        self,
        block: Block,
        cluster: ClusterState,
        entry_zone: Optional[str] = None,
    ) -> Tuple[Optional[ControllerState], Optional[str], str]:
        """Return (controller, zone_restriction, trace note)."""
        if block.controller is None:
            ctl = self._round_robin_controller(cluster)
            if ctl is None:
                return None, None, "no available controller in deployment"
            return ctl, None, f"no controller clause → round-robin pick {ctl.name!r}"

        clause = block.controller
        assert clause is not None
        tol = clause.topology_tolerance
        designated = cluster.controllers.get(clause.label)
        if designated is not None and designated.available:
            # Mirrors the compiled path: federated entry evaluation pins
            # tolerance none/same candidates to the designated home zone.
            if entry_zone is not None and tol is not TopologyTolerance.ALL:
                return (
                    designated,
                    designated.zone,
                    f"designated controller {clause.label!r} available "
                    f"(tolerance={tol.value} → workers pinned to zone "
                    f"{designated.zone!r})",
                )
            return designated, None, f"designated controller {clause.label!r} available"

        # Designated controller missing/unavailable → topology_tolerance.
        designated_zone = designated.zone if designated is not None else None
        if tol is TopologyTolerance.NONE:
            return (
                None,
                None,
                f"controller {clause.label!r} unavailable, tolerance=none → block invalid",
            )
        alternative = self._round_robin_controller(cluster)
        if alternative is None:
            return None, None, "no alternative controller available"
        if tol is TopologyTolerance.SAME:
            if designated_zone is None:
                return (
                    None,
                    None,
                    f"controller {clause.label!r} unknown and tolerance=same → "
                    f"cannot resolve its zone, block invalid",
                )
            return (
                alternative,
                designated_zone,
                f"controller {clause.label!r} unavailable, tolerance=same → "
                f"{alternative.name!r} restricted to zone {designated_zone!r}",
            )
        return (
            alternative,
            None,
            f"controller {clause.label!r} unavailable, tolerance=all → "
            f"{alternative.name!r}",
        )

    def _round_robin_controller(
        self, cluster: ClusterState
    ) -> Optional[ControllerState]:
        controllers = [c for c in cluster.controllers.values() if c.available]
        if not controllers:
            return None
        ctl = controllers[self._controller_cursor % len(controllers)]
        self._controller_cursor += 1
        return ctl

    # -- candidate expansion ----------------------------------------------------

    def _expand_block_candidates(
        self,
        invocation: Invocation,
        block: Block,
        views: Sequence[WorkerView],
        view_map: Dict[str, WorkerView],
    ):
        """Yield (worker, resolved ConstraintSpec) in trial order.

        Orderings are consumed lazily (:func:`iter_ordered`): a random
        strategy draws one candidate at a time, so stopping at the first
        valid worker consumes exactly as many RNG draws as candidates
        tried — the contract the compiled paths mirror.
        """
        if not block.uses_sets:
            # Explicit wrk list: the block-level strategy orders the list.
            strategy = block.strategy or Strategy.BEST_FIRST
            if strategy is Strategy.WARM_FIRST:
                items = _warm_item_order(
                    list(block.workers), view_map, invocation.hash
                )
            else:
                items = iter_ordered(
                    list(block.workers),
                    strategy,
                    rng=self._rng,
                    function_hash=invocation.hash,
                )
            for item in items:
                assert isinstance(item, WorkerRef)
                view = view_map.get(item.label)
                if view is None:
                    # Unknown label ⇒ treated as unreachable: emit a stub so the
                    # trace shows why it was skipped.
                    ghost = WorkerState(name=item.label, reachable=False)
                    yield ghost, resolve_constraints(item, block)
                    continue
                yield view.worker, resolve_constraints(item, block)
            return

        # Set list: block-level strategy orders the *set items*; each set's
        # inner strategy orders its members. Distribution-view tiering
        # (local-first) is preserved within each set expansion.
        strategy = block.strategy or Strategy.BEST_FIRST
        if strategy is Strategy.WARM_FIRST:
            set_items = _interp_warm_set_order(
                list(block.workers), views, invocation.hash
            )
        else:
            set_items = iter_ordered(
                list(block.workers),
                strategy,
                rng=self._rng,
                function_hash=invocation.hash,
            )
        for item in set_items:
            assert isinstance(item, WorkerSet)
            members = [v for v in views if v.worker.in_set(item.label)]
            local = [v.worker for v in members if v.local]
            foreign = [v.worker for v in members if not v.local]
            inner = item.strategy or Strategy.PLATFORM  # the platform default
            spec = resolve_constraints(item, block)
            if inner is Strategy.WARM_FIRST:
                for worker in _warm_worker_order(local, invocation.hash):
                    yield worker, spec
                for worker in _warm_worker_order(foreign, invocation.hash):
                    yield worker, spec
                continue
            for worker in iter_ordered(
                local, inner, rng=self._rng, function_hash=invocation.hash
            ):
                yield worker, spec
            for worker in iter_ordered(
                foreign, inner, rng=self._rng, function_hash=invocation.hash
            ):
                yield worker, spec
