"""Baseline: vanilla OpenWhisk scheduling (paper §2), topology-agnostic.

This is the comparison system of every experiment in the paper, so it is
implemented as a first-class scheduler:

* the gateway (Nginx) forwards requests to controllers **round-robin**
  (hard-coded, §4.3);
* each controller runs **co-prime scheduling** (§2 footnotes 5–6): the
  function's hash selects a *home* (primary) worker — the same function
  always lands on the same worker when it is usable, which implements
  OpenWhisk's code-locality caching — and a co-prime step size walks the
  remaining workers when the preceding ones are overloaded;
* the only invalidation is worker overload/unreachability — there is no
  notion of zones, sets, or data locality, which is exactly the failure
  mode of §5.1 (the MQTT function repeatedly lands on the cloud worker).
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.scheduler.engine import (
    Invocation,
    Outcome,
    ScheduleDecision,
    TraceEvent,
)
from repro.core.scheduler.state import ClusterState, WorkerState
from repro.core.scheduler.strategy import coprime_order_cached


class VanillaScheduler:
    """Round-robin gateway + co-prime controller schedule."""

    def __init__(self) -> None:
        self._controller_cursor = 0

    def scheduling_state(self):
        """Snapshot the round-robin cursor (probe/what-if rollback)."""
        return self._controller_cursor

    def restore_scheduling_state(self, state) -> None:
        self._controller_cursor = state

    def schedule(
        self,
        invocation: Invocation,
        cluster: ClusterState,
        *,
        trace: bool = False,
        entry_zone: Optional[str] = None,
    ) -> ScheduleDecision:
        """Vanilla co-prime schedule; ``entry_zone`` restricts the worker
        pool to one zone (the federation's policy-free zone-local pass) —
        vanilla stays topology-blind *within* that pool, exactly as the
        baseline is zone-blind over the whole cluster when unset."""
        decision = ScheduleDecision(outcome=Outcome.FAILED, tag=None)
        tr = decision.trace if trace else None
        controllers = [c for c in cluster.controllers.values() if c.available]
        if not controllers:
            if tr is not None:
                tr.append(TraceEvent("controller", "no available controller"))
            return decision
        controller = controllers[self._controller_cursor % len(controllers)]
        self._controller_cursor += 1
        if tr is not None:
            tr.append(
                TraceEvent(
                    "controller",
                    f"round-robin → {controller.name!r} (vanilla gateway)",
                )
            )

        workers: List[WorkerState] = [
            w for w in cluster.workers.values()
            if entry_zone is None or w.zone == entry_zone
        ]
        if not workers:
            if tr is not None:
                tr.append(TraceEvent("candidate", "no workers"))
            return decision

        for idx in coprime_order_cached(len(workers), invocation.hash):
            worker = workers[idx]
            if not worker.reachable:
                if tr is not None:
                    tr.append(
                        TraceEvent("candidate", f"{worker.name}: unreachable")
                    )
                continue
            if worker.overloaded:
                if tr is not None:
                    tr.append(
                        TraceEvent(
                            "candidate",
                            f"{worker.name}: overloaded "
                            f"({worker.inflight}/{worker.capacity_slots})",
                        )
                    )
                continue
            decision.outcome = Outcome.SCHEDULED
            decision.controller = controller.name
            decision.worker = worker.name
            if tr is not None:
                tr.append(
                    TraceEvent(
                        "candidate", f"{worker.name}: VALID (co-prime home)"
                    )
                )
            return decision

        if tr is not None:
            tr.append(
                TraceEvent("followup", "all workers overloaded → fail (vanilla)")
            )
        return decision
