"""Topology-based worker distribution policies (paper §4.4).

At deployment time, DevOps pick the access policy all controllers follow
when reaching for workers inside/outside their zone:

* ``default``   — every controller may use every worker, but each worker's
  capacity is *split* evenly among controllers (the original OpenWhisk
  resource model), with co-located workers prioritised (our extension's
  behaviour in §5.4.1).
* ``min_memory`` — foreign controllers get only a *minimal fraction* of a
  worker's resources (one invocation slot, OpenWhisk's 256MB analogue).
  Workers whose zone hosts no controller fall back to ``default`` splitting.
* ``isolated``  — controllers may only use co-located workers.
* ``shared``    — co-located workers first at full capacity; foreign
  workers only after the local ones are exhausted.

The policy is expressed as a *view*: the ordered list of workers a
controller may consider, each with the effective slot capacity that
controller may occupy. The scheduling engine evaluates tAPP policies
against this view, so distribution policies compose with every strategy
and invalidate condition.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence

from repro.core.scheduler.state import ClusterState, WorkerState


class DistributionPolicy(enum.Enum):
    DEFAULT = "default"
    MIN_MEMORY = "min_memory"
    ISOLATED = "isolated"
    SHARED = "shared"

    @classmethod
    def parse(cls, text: str) -> "DistributionPolicy":
        try:
            return cls(text.strip())
        except ValueError:
            raise ValueError(
                f"unknown distribution policy {text!r}; expected one of "
                f"{[p.value for p in cls]}"
            ) from None


@dataclasses.dataclass(frozen=True)
class WorkerView:
    """A controller's entitlement on one worker under a distribution policy.

    ``slot_cap`` bounds how many of the worker's concurrent slots this
    controller may occupy. ``tier`` orders candidates: tier 0 (local) is
    always tried before tier 1 (foreign); ``shared`` additionally requires
    tier-0 exhaustion before tier 1 becomes eligible, which is exactly the
    invalidation cascade, so the engine needs no special case.
    """

    worker: WorkerState
    local: bool
    slot_cap: int
    controller: str = ""

    @property
    def tier(self) -> int:
        return 0 if self.local else 1

    @property
    def saturated(self) -> bool:
        """This controller's entitlement on the worker is used up.

        The entitlement is consumed by *this controller's* admissions (the
        paper's per-controller resource reservation); global load is handled
        separately by the tAPP invalidate conditions.
        """
        own = self.worker.inflight_for(self.controller)
        return own >= min(self.slot_cap, self.worker.capacity_slots)


def distribution_view(
    cluster: ClusterState,
    controller_zone: str,
    policy: DistributionPolicy,
    *,
    controller_name: str = "",
    zone_restriction: Optional[str] = None,
) -> List[WorkerView]:
    """The ordered worker view of a controller in ``controller_zone``.

    ``zone_restriction`` implements ``topology_tolerance: same``: when set,
    only workers of that zone are visible regardless of the distribution
    policy tiering (the tolerance is a *function*-level constraint and takes
    precedence over deployment-level resource sharing).
    """
    n_controllers = max(1, len(cluster.controllers))
    views: List[WorkerView] = []
    for worker in cluster.workers.values():
        if zone_restriction is not None and worker.zone != zone_restriction:
            continue
        local = worker.zone == controller_zone
        view = _entitlement(cluster, worker, local, policy, n_controllers)
        if view is not None:
            views.append(
                WorkerView(
                    worker=view.worker,
                    local=view.local,
                    slot_cap=view.slot_cap,
                    controller=controller_name,
                )
            )
    # Stable order: local tier first, then foreign; preserve insertion order
    # within a tier so best_first means "order of appearance" deterministically.
    views.sort(key=lambda v: v.tier)
    return views


def _entitlement(
    cluster: ClusterState,
    worker: WorkerState,
    local: bool,
    policy: DistributionPolicy,
    n_controllers: int,
) -> Optional[WorkerView]:
    cap = worker.capacity_slots
    if policy is DistributionPolicy.DEFAULT:
        # Capacity split evenly among all controllers (racing access).
        split = max(1, cap // n_controllers)
        return WorkerView(worker=worker, local=local, slot_cap=split)
    if policy is DistributionPolicy.MIN_MEMORY:
        if local:
            return WorkerView(worker=worker, local=True, slot_cap=cap)
        # Foreign controllers: minimal fraction (one invocation slot). When
        # the worker's zone hosts no controller at all, fall back to the
        # default splitting (paper §4.4).
        if not cluster.controllers_in_zone(worker.zone):
            split = max(1, cap // n_controllers)
            return WorkerView(worker=worker, local=False, slot_cap=split)
        return WorkerView(worker=worker, local=False, slot_cap=1)
    if policy is DistributionPolicy.ISOLATED:
        if local:
            return WorkerView(worker=worker, local=True, slot_cap=cap)
        return None
    if policy is DistributionPolicy.SHARED:
        # Full capacity everywhere; tier ordering enforces local-first and
        # foreign workers are only reached after locals invalidate.
        return WorkerView(worker=worker, local=local, slot_cap=cap)
    raise ValueError(f"unknown distribution policy {policy!r}")


def views_by_name(views: Sequence[WorkerView]) -> Dict[str, WorkerView]:
    return {v.worker.name: v for v in views}


# ---------------------------------------------------------------------------
# Epoch-cached views (the compiled fast path)
# ---------------------------------------------------------------------------


class ViewCacheEntry:
    """A memoized distribution view plus derived lookup structures.

    The entry holds *live* :class:`WorkerState` references, so volatile
    load signals (inflight, capacity_used_pct) are always fresh; only the
    view's *shape* — membership, zoning, tiering, slot caps — is frozen,
    which is exactly what ``ClusterState.topology_epoch`` versions.
    Health/reachability are also read live (the invalidate predicates see
    them through the worker reference), though the watcher conservatively
    bumps the epoch on those transitions as well.
    Set-member expansions are resolved lazily per set label and retain the
    view's local-tier-first candidate order.
    """

    __slots__ = ("views", "by_name", "_set_members")

    def __init__(self, views: List[WorkerView]) -> None:
        self.views = views
        self.by_name: Dict[str, WorkerView] = {v.worker.name: v for v in views}
        self._set_members: Dict = {}

    def set_members(self, label):
        """(local views, foreign views) matching a tAPP set label."""
        hit = self._set_members.get(label)
        if hit is None:
            members = [v for v in self.views if v.worker.in_set(label)]
            hit = (
                [v for v in members if v.local],
                [v for v in members if not v.local],
            )
            self._set_members[label] = hit
        return hit


def cached_view_entry(
    cluster: ClusterState,
    controller_zone: str,
    policy: DistributionPolicy,
    *,
    controller_name: str = "",
    zone_restriction: Optional[str] = None,
) -> ViewCacheEntry:
    """Memoized :func:`distribution_view` keyed by ``(controller, policy,
    zone_restriction)``; the cache lives on the cluster snapshot and is
    cleared whenever ``topology_epoch`` bumps, so inflight-counter churn
    (admissions/completions) never causes a rebuild."""
    key = (controller_zone, controller_name, policy, zone_restriction)
    entry = cluster.view_cache.get(key)
    if entry is None:
        entry = ViewCacheEntry(
            distribution_view(
                cluster,
                controller_zone,
                policy,
                controller_name=controller_name,
                zone_restriction=zone_restriction,
            )
        )
        cluster.view_cache[key] = entry
    return entry
