"""Topology-based worker distribution policies (paper §4.4).

At deployment time, DevOps pick the access policy all controllers follow
when reaching for workers inside/outside their zone:

* ``default``   — every controller may use every worker, but each worker's
  capacity is *split* evenly among controllers (the original OpenWhisk
  resource model), with co-located workers prioritised (our extension's
  behaviour in §5.4.1).
* ``min_memory`` — foreign controllers get only a *minimal fraction* of a
  worker's resources (one invocation slot, OpenWhisk's 256MB analogue).
  Workers whose zone hosts no controller fall back to ``default`` splitting.
* ``isolated``  — controllers may only use co-located workers.
* ``shared``    — co-located workers first at full capacity; foreign
  workers only after the local ones are exhausted.

The policy is expressed as a *view*: the ordered list of workers a
controller may consider, each with the effective slot capacity that
controller may occupy. The scheduling engine evaluates tAPP policies
against this view, so distribution policies compose with every strategy
and invalidate condition.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler.state import ClusterState, WorkerState
from repro.core.scheduler.strategy import coprime_order_cached, randbelow


class DistributionPolicy(enum.Enum):
    DEFAULT = "default"
    MIN_MEMORY = "min_memory"
    ISOLATED = "isolated"
    SHARED = "shared"

    @classmethod
    def parse(cls, text: str) -> "DistributionPolicy":
        try:
            return cls(text.strip())
        except ValueError:
            raise ValueError(
                f"unknown distribution policy {text!r}; expected one of "
                f"{[p.value for p in cls]}"
            ) from None


@dataclasses.dataclass(frozen=True)
class WorkerView:
    """A controller's entitlement on one worker under a distribution policy.

    ``slot_cap`` bounds how many of the worker's concurrent slots this
    controller may occupy. ``tier`` orders candidates: tier 0 (local) is
    always tried before tier 1 (foreign); ``shared`` additionally requires
    tier-0 exhaustion before tier 1 becomes eligible, which is exactly the
    invalidation cascade, so the engine needs no special case.
    """

    worker: WorkerState
    local: bool
    slot_cap: int
    controller: str = ""

    @property
    def tier(self) -> int:
        return 0 if self.local else 1

    @property
    def saturated(self) -> bool:
        """This controller's entitlement on the worker is used up.

        The entitlement is consumed by *this controller's* admissions (the
        paper's per-controller resource reservation); global load is handled
        separately by the tAPP invalidate conditions.
        """
        own = self.worker.inflight_for(self.controller)
        return own >= min(self.slot_cap, self.worker.capacity_slots)


def distribution_view(
    cluster: ClusterState,
    controller_zone: str,
    policy: DistributionPolicy,
    *,
    controller_name: str = "",
    zone_restriction: Optional[str] = None,
) -> List[WorkerView]:
    """The ordered worker view of a controller in ``controller_zone``.

    ``zone_restriction`` implements ``topology_tolerance: same``: when set,
    only workers of that zone are visible regardless of the distribution
    policy tiering (the tolerance is a *function*-level constraint and takes
    precedence over deployment-level resource sharing).
    """
    n_controllers = max(1, len(cluster.controllers))
    views: List[WorkerView] = []
    if zone_restriction is not None:
        # Zone-restricted views scan only that zone's members (same
        # insertion order as filtering the full worker dict), so a
        # zone-local rebuild costs O(zone workers), not O(cluster).
        source = cluster.workers_by_zone(zone_restriction)
    else:
        source = cluster.workers.values()
    for worker in source:
        if zone_restriction is not None and worker.zone != zone_restriction:
            continue
        local = worker.zone == controller_zone
        view = _entitlement(cluster, worker, local, policy, n_controllers)
        if view is not None:
            views.append(
                WorkerView(
                    worker=view.worker,
                    local=view.local,
                    slot_cap=view.slot_cap,
                    controller=controller_name,
                )
            )
    # Stable order: local tier first, then foreign; within a tier, workers
    # the failure detector marks SUSPECT sort after healthy peers (they
    # stay placeable — last resort, not excluded); preserve insertion
    # order otherwise so best_first means "order of appearance"
    # deterministically. SUSPECT transitions are structural (epoch bump),
    # so the cached view's order is always current, and the sort is
    # stable, so a suspect-free cluster orders bit-identically to before.
    views.sort(key=lambda v: (v.tier, v.worker.suspect))
    return views


def _entitlement(
    cluster: ClusterState,
    worker: WorkerState,
    local: bool,
    policy: DistributionPolicy,
    n_controllers: int,
) -> Optional[WorkerView]:
    cap = worker.capacity_slots
    if policy is DistributionPolicy.DEFAULT:
        # Capacity split evenly among all controllers (racing access).
        split = max(1, cap // n_controllers)
        return WorkerView(worker=worker, local=local, slot_cap=split)
    if policy is DistributionPolicy.MIN_MEMORY:
        if local:
            return WorkerView(worker=worker, local=True, slot_cap=cap)
        # Foreign controllers: minimal fraction (one invocation slot). When
        # the worker's zone hosts no controller at all, fall back to the
        # default splitting (paper §4.4).
        if not cluster.controllers_in_zone(worker.zone):
            split = max(1, cap // n_controllers)
            return WorkerView(worker=worker, local=False, slot_cap=split)
        return WorkerView(worker=worker, local=False, slot_cap=1)
    if policy is DistributionPolicy.ISOLATED:
        if local:
            return WorkerView(worker=worker, local=True, slot_cap=cap)
        return None
    if policy is DistributionPolicy.SHARED:
        # Full capacity everywhere; tier ordering enforces local-first and
        # foreign workers are only reached after locals invalidate.
        return WorkerView(worker=worker, local=local, slot_cap=cap)
    raise ValueError(f"unknown distribution policy {policy!r}")


def views_by_name(views: Sequence[WorkerView]) -> Dict[str, WorkerView]:
    return {v.worker.name: v for v in views}


# ---------------------------------------------------------------------------
# Epoch-cached views (the compiled fast path)
# ---------------------------------------------------------------------------


class ViewCacheEntry:
    """A memoized distribution view plus derived lookup structures.

    The entry holds *live* :class:`WorkerState` references, so volatile
    load signals (inflight, capacity_used_pct) are always fresh; only the
    view's *shape* — membership, zoning, tiering, slot caps — is frozen,
    which is exactly what ``ClusterState.topology_epoch`` versions.
    Health/reachability are also read live (the invalidate predicates see
    them through the worker reference), though the watcher conservatively
    bumps the epoch on those transitions as well.
    Set-member expansions are resolved lazily per set label and retain the
    view's local-tier-first candidate order.
    """

    __slots__ = ("views", "by_name", "_set_members", "_block_indexes")

    def __init__(self, views: List[WorkerView]) -> None:
        self.views = views
        self.by_name: Dict[str, WorkerView] = {v.worker.name: v for v in views}
        self._set_members: Dict = {}
        self._block_indexes: Dict = {}

    def set_members(self, label):
        """(local views, foreign views) matching a tAPP set label."""
        hit = self._set_members.get(label)
        if hit is None:
            members = [v for v in self.views if v.worker.in_set(label)]
            hit = (
                [v for v in members if v.local],
                [v for v in members if not v.local],
            )
            self._set_members[label] = hit
        return hit

    def block_index(self, cblock) -> "BlockIndex":
        """The candidate index of one compiled block under this view.

        Built once per (view entry × compiled block) — i.e. at
        ``topology_epoch`` granularity, since entries die with the epoch —
        and keyed by block identity (compiled blocks are identity-hashed).
        """
        hit = self._block_indexes.get(cblock)
        if hit is None:
            hit = BlockIndex(self, cblock)
            self._block_indexes[cblock] = hit
        return hit


# ---------------------------------------------------------------------------
# Candidate indexes (the O(1)-per-decision layer)
# ---------------------------------------------------------------------------
#
# A BlockIndex materializes, per (view entry × compiled block), everything
# about candidate selection that is *epoch-static*: which workers are in
# play at all (view membership, set membership, zone restriction,
# reachability/health — the static half of the constraint split), and the
# orders the strategies try them in (best_first = canonical position
# order; platform = co-prime orders materialized per function hash).
# On top sits one *availability bitmask* per worker item: bit i is set
# iff candidate i currently passes its item's dynamic constraint residue
# AND the controller's entitlement on it is unsaturated. The mask is
# maintained incrementally — the admission ledger logs each touched
# worker on ClusterState.note_worker_load, and refresh() re-derives only
# that worker's bits — so a scheduling decision is "first set bit in
# precomputed order" and a fully saturated cluster answers in O(1)
# without rescanning a single invalid candidate.

_CHUNK = 64  # platform-order chunk width (one int AND skips 64 candidates)
# Per-index bound on materialized platform orders (one per distinct
# function hash). A FaaS population can have unbounded function
# cardinality within one topology epoch; past the cap the dict is
# cleared and orders re-materialize on demand (they are pure functions
# of (index shape, fhash), so eviction never affects decisions).
_PLATFORM_ORDER_CACHE = 512


def _draw_first_avail(arr: List[int], avail: int, rng) -> Optional[int]:
    """First available position of one tier in lazy-Fisher–Yates order.

    Draw-for-draw identical to iterating
    :func:`~repro.core.scheduler.strategy.iter_random` over the tier and
    rejecting unavailable candidates — which is exactly what the
    interpreter and the traced compiled path do — so RNG streams stay in
    lockstep across all evaluation paths. ``arr`` is the index's reusable
    scratch permutation; the swap trail is undone before returning, so
    the scratch stays canonical without an O(n) copy per decision.
    """
    n = len(arr)
    if n == 0:
        return None
    getrandbits = rng.getrandbits
    found: Optional[int] = None
    swaps: List[Tuple[int, int]] = []
    for i in range(n - 1, 0, -1):
        j = randbelow(getrandbits, i + 1)
        if j != i:
            arr[i], arr[j] = arr[j], arr[i]
            swaps.append((i, j))
        p = arr[i]
        if (avail >> p) & 1:
            found = p
            break
    else:
        p = arr[0]
        if (avail >> p) & 1:
            found = p
    for i, j in reversed(swaps):
        arr[i], arr[j] = arr[j], arr[i]
    return found


# Monotonic ItemIndex serial source; itertools.count.__next__ is atomic
# in CPython, so concurrent index builds never share a serial.
_ITEM_INDEX_SERIAL = itertools.count()


class ItemIndex:
    """Pre-filtered, pre-ordered candidates of one worker item.

    Positions are canonical trial order: for a ``wrk`` list, the item
    positions in block source order; for a ``set`` item, the view's
    members local tier first (insertion order within a tier) — so
    ``best_first`` is literally "lowest set bit of the availability
    mask". Statically-invalid candidates (ghost labels, unreachable or
    — for ``overload`` — unhealthy workers) are excluded from
    ``static_mask`` at build time and can never turn available within
    the epoch.
    """

    __slots__ = (
        "serial",
        "workers",
        "views",
        "dyns",
        "n",
        "n_local",
        "static_mask",
        "avail",
        "_static_positions",
        "_by_worker",
        "_zones",
        "_synced",
        "_synced_total",
        "_platform_chunks",
        "_scratch_local",
        "_scratch_foreign",
        "_sat_ctls",
        "_sat_caps",
        "_replay_limit",
        "_bits",
        "_single_zone",
        "_warm_masks",
        "_warm_synced",
        "_warm_positions",
        "_warm_by_worker",
        "local_mask",
    )

    def __init__(self, candidates, n_local: int) -> None:
        # candidates: sequence of (worker|None, view|None, static_fn, dyn_fn)
        # Process-unique monotonic id: external caches (the batch
        # router's mask planes) key on it instead of id(self), which a
        # later index could legally re-use after this one is collected.
        self.serial = next(_ITEM_INDEX_SERIAL)
        self.n = len(candidates)
        self.n_local = n_local
        # Local-tier bit mask (wrk lists are untiered: every position is
        # "local"); the warm-first pick partitions within each tier.
        self.local_mask = (1 << n_local) - 1
        self.workers = [c[0] for c in candidates]
        self.views = [c[1] for c in candidates]
        self.dyns = [c[3] for c in candidates]
        # Flattened WorkerView.saturated inputs: the controller key into
        # worker.inflight_by and min(slot_cap, capacity_slots). Both are
        # epoch-static (capacity changes are structural → the entry, and
        # this index with it, dies at the epoch bump), so the per-event
        # bit re-derivation pays one dict.get instead of two property
        # calls through the view.
        self._sat_ctls = [
            v.controller if v is not None else "" for v in self.views
        ]
        self._sat_caps = [
            min(v.slot_cap, v.worker.capacity_slots) if v is not None else 0
            for v in self.views
        ]
        static_mask = 0
        static_positions: List[int] = []
        by_worker: Dict[str, List[int]] = {}
        zones: List[str] = []
        for pos, (worker, _view, static_fn, _dyn) in enumerate(candidates):
            if worker is None or static_fn(worker):
                continue
            static_mask |= 1 << pos
            static_positions.append(pos)
            by_worker.setdefault(worker.name, []).append(pos)
            if worker.zone not in zones:
                zones.append(worker.zone)
        self.static_mask = static_mask
        self._static_positions = static_positions
        self._by_worker = {k: tuple(v) for k, v in by_worker.items()}
        # Replay cutoff: more pending events than candidate workers makes
        # a full recompute cheaper than replay (precomputed — refresh
        # runs once per decision).
        self._replay_limit = max(1, len(self._by_worker))
        # Per-position bit masks: at 1024 candidates the avail mask is a
        # 1024-bit int, so `1 << pos` and the read-modify-write both
        # allocate. Precomputing the masks and skipping the write when
        # the bit already has the right value keeps the per-event
        # re-derivation flat in candidate count (bits rarely flip).
        self._bits = [1 << pos for pos in range(self.n)]
        # Load-log shards this index's candidates span; refresh replays
        # only these, so foreign-zone churn never costs a replayed event.
        self._zones: Tuple[str, ...] = tuple(zones)
        self._single_zone = len(zones) == 1
        # Dynamic bits are computed on the first refresh (an index is
        # built for a whole block at once, but an item may first be
        # *reached* many decisions — and many ledger events — later).
        # Cursor: the zone shard's seq (single-zone index) or the merged
        # journal's seq (multi-zone); None until the first refresh.
        self._synced = None
        self._synced_total = -1
        self._platform_chunks: Dict[int, Tuple] = {}
        self._scratch_local: Optional[List[int]] = None
        self._scratch_foreign: Optional[List[int]] = None
        self.avail = 0
        # Warm bitmasks, one per function hash, over ALL non-None
        # positions (not just static survivors): the interpreter's
        # warm-first partition orders the raw candidate list before
        # validity is tried, so the mask must agree on every position.
        # Extra bits are harmless to picks (they AND with avail).
        # Maintained incrementally against the cluster's warm journal.
        self._warm_masks: Dict[int, int] = {}
        self._warm_synced = 0
        warm_positions = [
            pos for pos, c in enumerate(candidates) if c[0] is not None
        ]
        self._warm_positions = warm_positions
        warm_by: Dict[str, List[int]] = {}
        for pos in warm_positions:
            warm_by.setdefault(self.workers[pos].name, []).append(pos)
        self._warm_by_worker = {k: tuple(v) for k, v in warm_by.items()}

    def static_survivors(self):
        """``(position, worker, saturation cap)`` of every static survivor.

        The saturation cap is ``min(view.slot_cap, capacity_slots)`` — the
        exact per-controller entitlement the availability mask saturates
        against — so static analyzers can bound admissions without
        re-deriving the distribution policy. Read-only view over
        epoch-static state; never triggers a dynamic refresh.
        """
        workers = self.workers
        caps = self._sat_caps
        return [(pos, workers[pos], caps[pos]) for pos in self._static_positions]

    # -- availability maintenance ------------------------------------------

    def _recompute(self, positions) -> None:
        avail = self.avail
        workers = self.workers
        dyns = self.dyns
        ctls = self._sat_ctls
        caps = self._sat_caps
        bits = self._bits
        for pos in positions:
            worker = workers[pos]
            bit = bits[pos]
            if (
                dyns[pos](worker)
                or worker.inflight_by.get(ctls[pos], 0) >= caps[pos]
            ):
                if avail & bit:
                    avail &= ~bit
            elif not avail & bit:
                avail |= bit
        self.avail = avail

    def refresh(self, cluster: ClusterState) -> int:
        """Bring the availability mask up to date with the load log.

        O(events since last refresh): a single-zone index replays its
        zone's shard (foreign churn costs it nothing), a multi-zone
        index replays the cluster's merged journal (never an O(zones)
        shard-cursor scan). Replayed events are deduplicated per touched
        worker before any bit re-derivation — a churn window that
        hammers one worker costs one ``_recompute``, not one per event.
        A decision on an otherwise idle index is a single integer
        comparison.
        """
        total = cluster._load_total
        if total == self._synced_total:
            return self.avail
        if self._single_zone:
            zone = self._zones[0]
            shard = cluster.load_shards.get(zone)
            # Capture trimmed before log (writers advance trimmed, then
            # swap in a fresh list): a torn read across a concurrent
            # compaction can only look over-trimmed, which lands on the
            # full-recompute branch instead of replaying a wrong window.
            if shard is not None:
                trimmed = shard.trimmed
                log = shard.log
                seq = trimmed + len(log)
            else:
                trimmed = seq = 0
                log = ()
            synced = self._synced
            if synced is None:
                # First use: derive all dynamic bits from live state.
                self._recompute(self._static_positions)
            elif seq != synced:
                if (
                    shard is None
                    or synced < trimmed
                    or seq - synced >= self._replay_limit
                ):
                    # Compacted past our cursor, or more events than
                    # candidates: a full recompute is cheaper than replay.
                    self._recompute(self._static_positions)
                else:
                    self._replay_window(log, synced - trimmed)
            self._synced = seq
            self._synced_total = total
            return self.avail
        # Multi-zone candidates: replay the cluster's merged journal
        # (all zones interleaved, seq == _load_total) from our last
        # synced total — O(events since last sync) regardless of how
        # many zones exist. Foreign-worker names simply miss in
        # _by_worker. Scanning per-zone shards here instead would cost
        # O(zones) cursor checks per decision even on an idle cluster.
        if self._synced is None:
            self._recompute(self._static_positions)
            self._synced = total
            self._synced_total = total
            return self.avail
        journal = cluster._load_journal
        old = self._synced_total
        # Same trimmed-then-log capture order as the single-zone path:
        # racing a journal compaction degrades to a recompute, never a
        # mis-sliced replay window.
        trimmed = journal.trimmed
        log = journal.log
        if old < trimmed or total - old >= self._replay_limit:
            # Compacted past our cursor, or more events than candidates:
            # a full recompute is cheaper than replay.
            self._recompute(self._static_positions)
        else:
            self._replay_window(log, old - trimmed)
        self._synced_total = total
        return self.avail

    def _replay_window(self, log: List[str], start: int) -> None:
        by = self._by_worker
        end = len(log)
        if end - start <= 4:
            # Tiny window — the admission ledger's admit/complete pairs
            # put the same name in consecutive events, so a running
            # last-name check dedups without allocating a slice + set,
            # and the bit re-derivation is inlined (this path runs once
            # per churned decision; the _recompute call chain is
            # measurable at that rate).
            workers = self.workers
            dyns = self.dyns
            ctls = self._sat_ctls
            caps = self._sat_caps
            bits = self._bits
            avail = self.avail
            prev = None
            for i in range(start, end):
                name = log[i]
                if name != prev:
                    prev = name
                    positions = by.get(name)
                    if positions is not None:
                        for pos in positions:
                            worker = workers[pos]
                            bit = bits[pos]
                            if (
                                dyns[pos](worker)
                                or worker.inflight_by.get(ctls[pos], 0)
                                >= caps[pos]
                            ):
                                if avail & bit:
                                    avail &= ~bit
                            elif not avail & bit:
                                avail |= bit
            self.avail = avail
            return
        # Satellite: dedup the window before re-deriving bits — each
        # distinct touched worker costs one _recompute regardless of how
        # many ledger events it produced.
        for name in set(log[start:]):
            positions = by.get(name)
            if positions is not None:
                self._recompute(positions)

    # -- strategy picks -----------------------------------------------------

    def pick_platform(self, avail: int, fhash: int) -> Optional[int]:
        """First available position in co-prime order, chunk-skipped."""
        chunks = self._platform_chunks.get(fhash)
        if chunks is None:
            chunks = self._build_platform_chunks(fhash)
        for mask, seg in chunks:
            if not (avail & mask):
                continue
            for p in seg:
                if (avail >> p) & 1:
                    return p
        return None

    def _build_platform_chunks(self, fhash: int) -> Tuple:
        """Materialize the per-tier co-prime order over static survivors.

        The permutation is taken over the *full* tier length (the
        interpreter hashes into the unfiltered candidate list) and then
        filtered, so survivor order matches the reference exactly.
        """
        n_local = self.n_local
        n_foreign = self.n - n_local
        smask = self.static_mask
        order = [
            p for p in coprime_order_cached(n_local, fhash) if (smask >> p) & 1
        ]
        order.extend(
            n_local + p
            for p in coprime_order_cached(n_foreign, fhash)
            if (smask >> (n_local + p)) & 1
        )
        chunks = []
        for k in range(0, len(order), _CHUNK):
            seg = tuple(order[k:k + _CHUNK])
            mask = 0
            for p in seg:
                mask |= 1 << p
            chunks.append((mask, seg))
        result = tuple(chunks)
        if len(self._platform_chunks) >= _PLATFORM_ORDER_CACHE:
            self._platform_chunks.clear()
        self._platform_chunks[fhash] = result
        return result

    def pick_random(self, avail: int, rng) -> Optional[int]:
        """First available position in lazy random order, local tier first.

        Consumes RNG draws even when ``avail`` is empty — the reference
        paths draw through the whole tier before moving on, and the
        streams must stay identical.
        """
        local = self._scratch_local
        if local is None:
            local = self._scratch_local = list(range(self.n_local))
            self._scratch_foreign = list(range(self.n_local, self.n))
        pos = _draw_first_avail(local, avail, rng)
        if pos is None:
            pos = _draw_first_avail(self._scratch_foreign, avail, rng)
        return pos

    # -- warm bitmasks (warm-first strategy) --------------------------------

    def _warm_recompute(self, fhash: int) -> int:
        """Derive one function's warm mask from live worker pool counts."""
        mask = 0
        workers = self.workers
        bits = self._bits
        for pos in self._warm_positions:
            if workers[pos].warm_idle.get(fhash, 0) > 0:
                mask |= bits[pos]
        self._warm_masks[fhash] = mask
        return mask

    def _warm_replay(self, log, start: int) -> None:
        by = self._warm_by_worker
        masks = self._warm_masks
        workers = self.workers
        bits = self._bits
        for i in range(start, len(log)):
            name, fh = log[i]
            cur = masks.get(fh)
            if cur is None:
                # Untracked function: its mask is fully recomputed on
                # first request, so the event needs no replay.
                continue
            positions = by.get(name)
            if positions is None:
                continue
            for pos in positions:
                if workers[pos].warm_idle.get(fh, 0) > 0:
                    cur |= bits[pos]
                else:
                    cur &= ~bits[pos]
            masks[fh] = cur

    def warm_mask(self, cluster: ClusterState, fhash: int) -> int:
        """Bit i set iff candidate i holds an IDLE warm instance of
        ``fhash``'s function.

        Incremental like :meth:`refresh`: replays the cluster's merged
        warm journal (``(name, fhash)`` events, emitted only on 0<->1
        pool-count flips) from the last synced cursor; over-trimmed or
        oversized windows fall back to a per-tracked-function recompute.
        With no lifecycle armed the journal never moves and every mask
        is the cached 0 — one dict hit per decision.
        """
        total = cluster._warm_total
        masks = self._warm_masks
        if total != self._warm_synced:
            journal = cluster._warm_journal
            # Same trimmed-then-log capture order as refresh(): a torn
            # read across compaction looks over-trimmed and recomputes.
            trimmed = journal.trimmed
            log = journal.log
            synced = self._warm_synced
            if masks:
                if synced < trimmed or total - synced >= self._replay_limit:
                    for fh in list(masks):
                        self._warm_recompute(fh)
                else:
                    self._warm_replay(log, synced - trimmed)
            self._warm_synced = total
        mask = masks.get(fhash)
        if mask is None:
            if len(masks) >= _PLATFORM_ORDER_CACHE:
                masks.clear()
            mask = self._warm_recompute(fhash)
        return mask

    def has_warm(self, cluster: ClusterState, fhash: int) -> bool:
        """Any candidate (valid or not) holds a warm instance — the
        set-item ordering key of a block-level ``warm-first``."""
        return self.warm_mask(cluster, fhash) != 0

    def platform_order(self, fhash: int) -> List[int]:
        """The flat per-fhash co-prime trial order over static survivors.

        The batch router stacks these into the ``select_first_available``
        kernel's int32 order planes; scanning the flat list position by
        position is exactly what :meth:`pick_platform` does (its chunking
        is only a skip optimization), so a kernel pick over this order is
        bit-identical to the scalar pick.
        """
        chunks = self._platform_chunks.get(fhash)
        if chunks is None:
            chunks = self._build_platform_chunks(fhash)
        order: List[int] = []
        for _mask, seg in chunks:
            order.extend(seg)
        return order


class BlockIndex:
    """Per-(view × compiled block) candidate indexes.

    ``wrk`` holds the single :class:`ItemIndex` of a wrk-list block
    (positions = item positions); ``sets`` holds one per set item
    (positions = that set's members, local tier first).
    """

    __slots__ = ("wrk", "sets")

    def __init__(self, entry: ViewCacheEntry, cblock) -> None:
        if cblock.uses_sets:
            self.wrk = None
            self.sets = tuple(
                _set_item_index(entry, item) for item in cblock.sets
            )
        else:
            self.wrk = _wrk_item_index(entry, cblock.wrks)
            self.sets = ()


def _wrk_item_index(entry: ViewCacheEntry, wrks) -> ItemIndex:
    candidates = []
    for item in wrks:
        view = entry.by_name.get(item.label)
        if view is None:
            # Ghost label, or filtered out by the zone restriction:
            # statically invalid for the whole epoch.
            candidates.append((None, None, None, None))
        else:
            candidates.append(
                (view.worker, view, item.static_invalid, item.dyn_invalid)
            )
    # wrk lists are untiered: strategies order the item list as a whole.
    return ItemIndex(candidates, n_local=len(candidates))


def _set_item_index(entry: ViewCacheEntry, item) -> ItemIndex:
    local, foreign = entry.set_members(item.label)
    static_fn = item.static_invalid
    dyn_fn = item.dyn_invalid
    candidates = [(v.worker, v, static_fn, dyn_fn) for v in local]
    candidates.extend((v.worker, v, static_fn, dyn_fn) for v in foreign)
    return ItemIndex(candidates, n_local=len(local))


def cached_view_entry(
    cluster: ClusterState,
    controller_zone: str,
    policy: DistributionPolicy,
    *,
    controller_name: str = "",
    zone_restriction: Optional[str] = None,
) -> ViewCacheEntry:
    """Memoized :func:`distribution_view` keyed by ``(controller, policy,
    zone_restriction)``; the cache lives on the cluster snapshot and is
    cleared whenever ``topology_epoch`` bumps, so inflight-counter churn
    (admissions/completions) never causes a rebuild."""
    key = (controller_zone, controller_name, policy, zone_restriction)
    entry = cluster.view_cache.get(key)
    if entry is None:
        entry = ViewCacheEntry(
            distribution_view(
                cluster,
                controller_zone,
                policy,
                controller_name=controller_name,
                zone_restriction=zone_restriction,
            )
        )
        cluster.view_cache[key] = entry
    return entry
