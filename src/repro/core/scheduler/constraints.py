"""The constraint layer: one predicate pipeline for worker validity.

This module replaces the original ``invalidate.py`` (the hardcoded
three-predicate special case of paper §3.3) with a composable predicate
IR. A tAPP worker item now carries a resolved :class:`ConstraintSpec` —
its invalidate condition plus optional affinity / anti-affinity clauses
(arXiv:2407.14572 semantics) — and both execution paths evaluate it
through this module:

* the **interpreter** calls :func:`constraint_reason` per candidate
  (reason strings double as trace output);
* the **compiled fast path** (:mod:`repro.core.tapp.compile`) lowers the
  spec once at script-compile time via :func:`compile_spec` into a flat
  pre-resolved closure, so per-decision cost stays O(candidates tried)
  regardless of how many constraint kinds a script stacks (the
  *Archipelago* flat-cost requirement).

Adding a constraint kind = one predicate dataclass with ``violated`` /
``reason`` / ``lower`` + a case in :func:`_predicate_of` — no engine or
compiler changes.

Resolution order of every clause applied to a worker item (paper §3.3,
extended): per-``wrk``/per-``set`` clause ▸ enclosing block clause ▸
platform default (``overload`` for invalidate; no affinity constraints).
All constraints share the *preliminary* condition: an unreachable worker
is always invalid.

Affinity semantics (documented in :mod:`repro.core.tapp.ast`): the
predicates read ``WorkerState.running_functions``, the live per-worker
multiset of admitted function executions fed by the controller runtime.
``affinity`` requires every listed function present; ``anti-affinity``
forbids any listed function present.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple, Union

from repro.core.scheduler.state import WorkerState
from repro.core.tapp.ast import (
    Affinity,
    AntiAffinity,
    CapacityUsed,
    Invalidate,
    MaxConcurrentInvocations,
    Overload,
)

# ``invalid(worker) -> bool``; takes anything WorkerState-shaped.
InvalidFn = Callable[[object], bool]

DEFAULT_INVALIDATE: Invalidate = Overload()


# ---------------------------------------------------------------------------
# Legacy invalidate API (paper §3.3) — thin shims over the predicate IR
# ---------------------------------------------------------------------------


def resolve_invalidate(
    item_level: Optional[Invalidate],
    block_level: Optional[Invalidate],
) -> Invalidate:
    """Inner condition overrides outer; fall back to the platform default."""
    if item_level is not None:
        return item_level
    if block_level is not None:
        return block_level
    return DEFAULT_INVALIDATE


def is_invalid(worker: WorkerState, condition: Invalidate) -> bool:
    """True iff the worker cannot host the execution under ``condition``."""
    if not worker.reachable:
        return True
    return _predicate_of(condition).violated(worker)


def invalid_reason(worker: WorkerState, condition: Invalidate) -> Optional[str]:
    """Human-readable reason the worker is invalid, or None if valid."""
    if not worker.reachable:
        return "unreachable"
    return _predicate_of(condition).reason(worker)


def compile_invalidate(condition: Invalidate) -> InvalidFn:
    """Pre-bind an invalidate condition to a branch-free predicate.

    Matches :func:`is_invalid` exactly, including the preliminary
    unreachability condition (paper §3.3), but resolves the condition type
    once at compile time instead of per candidate.
    """
    if isinstance(condition, Overload):
        def invalid(w) -> bool:
            return (
                (not w.reachable)
                or (not w.healthy)
                or w.inflight >= w.capacity_slots
            )
        return invalid
    if isinstance(condition, CapacityUsed):
        threshold = condition.percent

        def invalid(w) -> bool:
            return (not w.reachable) or w.capacity_used_pct >= threshold
        return invalid
    if isinstance(condition, MaxConcurrentInvocations):
        limit = condition.limit

        def invalid(w) -> bool:
            return (not w.reachable) or (w.inflight + w.queued) >= limit
        return invalid
    raise TypeError(f"unknown invalidate condition {condition!r}")


# ---------------------------------------------------------------------------
# ConstraintSpec: the fully resolved constraint set of one worker item
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConstraintSpec:
    """Everything that can invalidate a worker for one tAPP worker item."""

    invalidate: Invalidate = dataclasses.field(default_factory=Overload)
    affinity: Optional[Affinity] = None
    anti_affinity: Optional[AntiAffinity] = None

    @property
    def plain(self) -> bool:
        """No affinity clauses — the paper's original constraint set."""
        return self.affinity is None and self.anti_affinity is None

    def describe(self) -> str:
        parts = [self.invalidate.describe()]
        if self.affinity is not None:
            parts.append(self.affinity.describe())
        if self.anti_affinity is not None:
            parts.append(self.anti_affinity.describe())
        return "; ".join(parts)


def resolve_constraints(item, block) -> ConstraintSpec:
    """Resolve the effective spec of a worker item inside its block.

    ``item``/``block`` are any objects with ``invalidate`` / ``affinity`` /
    ``anti_affinity`` attributes (:class:`~repro.core.tapp.ast.WorkerRef`,
    :class:`~repro.core.tapp.ast.WorkerSet`, and
    :class:`~repro.core.tapp.ast.Block`). Each clause resolves
    independently: item-level overrides block-level; invalidate falls back
    to the platform default, affinity clauses to "unconstrained".
    """
    return ConstraintSpec(
        invalidate=resolve_invalidate(item.invalidate, block.invalidate),
        affinity=item.affinity if item.affinity is not None else block.affinity,
        anti_affinity=(
            item.anti_affinity
            if item.anti_affinity is not None
            else block.anti_affinity
        ),
    )


# ---------------------------------------------------------------------------
# Predicate IR
# ---------------------------------------------------------------------------
#
# A predicate states one *requirement* for a worker to be valid. The engine
# never evaluates these nodes directly on the hot path — `lower()` returns a
# pre-resolved closure, and `compile_spec` below fuses the common shapes into
# flat single-call closures — but the IR is the semantic definition every
# evaluation path must agree with, and the extension point for future
# constraint kinds (cost, latency-SLO, ...).


@dataclasses.dataclass(frozen=True)
class Reachable:
    """The preliminary condition: every policy requires reachability."""

    def violated(self, w: WorkerState) -> bool:
        return not w.reachable

    def reason(self, w: WorkerState) -> Optional[str]:
        return None if w.reachable else "unreachable"

    def lower(self) -> InvalidFn:
        return lambda w: not w.reachable


@dataclasses.dataclass(frozen=True)
class NotOverloaded:
    def violated(self, w: WorkerState) -> bool:
        return (not w.healthy) or w.inflight >= w.capacity_slots

    def reason(self, w: WorkerState) -> Optional[str]:
        if not w.healthy:
            return "unhealthy"
        if w.inflight >= w.capacity_slots:
            return f"slots exhausted ({w.inflight}/{w.capacity_slots})"
        return None

    def lower(self) -> InvalidFn:
        return lambda w: (not w.healthy) or w.inflight >= w.capacity_slots


@dataclasses.dataclass(frozen=True)
class CapacityBelow:
    percent: float

    def violated(self, w: WorkerState) -> bool:
        return w.capacity_used_pct >= self.percent

    def reason(self, w: WorkerState) -> Optional[str]:
        if w.capacity_used_pct >= self.percent:
            return (
                f"capacity_used {w.capacity_used_pct:.0f}% >= "
                f"{self.percent:.0f}%"
            )
        return None

    def lower(self) -> InvalidFn:
        threshold = self.percent
        return lambda w: w.capacity_used_pct >= threshold


@dataclasses.dataclass(frozen=True)
class ConcurrencyBelow:
    limit: int

    def violated(self, w: WorkerState) -> bool:
        return w.concurrent >= self.limit

    def reason(self, w: WorkerState) -> Optional[str]:
        if w.concurrent >= self.limit:
            return f"concurrent {w.concurrent} >= {self.limit}"
        return None

    def lower(self) -> InvalidFn:
        limit = self.limit
        return lambda w: (w.inflight + w.queued) >= limit


@dataclasses.dataclass(frozen=True)
class RunningAll:
    """Affinity: every listed function must be running on the worker."""

    functions: Tuple[str, ...]

    def violated(self, w: WorkerState) -> bool:
        rf = w.running_functions
        return any(rf.get(fn, 0) <= 0 for fn in self.functions)

    def reason(self, w: WorkerState) -> Optional[str]:
        rf = w.running_functions
        for fn in self.functions:
            if rf.get(fn, 0) <= 0:
                return f"affinity: requires {fn!r} running"
        return None

    def lower(self) -> InvalidFn:
        if len(self.functions) == 1:
            (fn,) = self.functions
            return lambda w: w.running_functions.get(fn, 0) <= 0
        fns = self.functions
        return lambda w: any(w.running_functions.get(f, 0) <= 0 for f in fns)


@dataclasses.dataclass(frozen=True)
class RunningNone:
    """Anti-affinity: no listed function may be running on the worker."""

    functions: Tuple[str, ...]

    def violated(self, w: WorkerState) -> bool:
        rf = w.running_functions
        return any(rf.get(fn, 0) > 0 for fn in self.functions)

    def reason(self, w: WorkerState) -> Optional[str]:
        rf = w.running_functions
        for fn in self.functions:
            count = rf.get(fn, 0)
            if count > 0:
                return f"anti-affinity: {fn!r} running ({count}x)"
        return None

    def lower(self) -> InvalidFn:
        if len(self.functions) == 1:
            (fn,) = self.functions
            return lambda w: w.running_functions.get(fn, 0) > 0
        fns = self.functions
        return lambda w: any(w.running_functions.get(f, 0) > 0 for f in fns)


@dataclasses.dataclass(frozen=True)
class Conjunction:
    """All requirements must hold; violated if ANY member is violated.

    Members are evaluated in order — reason strings report the first
    violation, matching the short-circuit order of the lowered closure.
    """

    predicates: Tuple["Predicate", ...]

    def violated(self, w: WorkerState) -> bool:
        return any(p.violated(w) for p in self.predicates)

    def reason(self, w: WorkerState) -> Optional[str]:
        for p in self.predicates:
            r = p.reason(w)
            if r is not None:
                return r
        return None

    def lower(self) -> InvalidFn:
        fns = tuple(p.lower() for p in self.predicates)
        if len(fns) == 1:
            return fns[0]
        if len(fns) == 2:
            a, b = fns
            return lambda w: a(w) or b(w)
        if len(fns) == 3:
            a, b, c = fns
            return lambda w: a(w) or b(w) or c(w)
        return lambda w: any(f(w) for f in fns)


Predicate = Union[
    Reachable,
    NotOverloaded,
    CapacityBelow,
    ConcurrencyBelow,
    RunningAll,
    RunningNone,
    Conjunction,
]


@functools.lru_cache(maxsize=1024)
def _predicate_of(condition: Invalidate) -> Predicate:
    # Memoized: conditions are frozen AST nodes, and the interpreter asks
    # per candidate — real deployments see a bounded set of conditions.
    if isinstance(condition, Overload):
        return NotOverloaded()
    if isinstance(condition, CapacityUsed):
        return CapacityBelow(condition.percent)
    if isinstance(condition, MaxConcurrentInvocations):
        return ConcurrencyBelow(condition.limit)
    raise TypeError(f"unknown invalidate condition {condition!r}")


@functools.lru_cache(maxsize=1024)
def spec_predicate(spec: ConstraintSpec) -> Conjunction:
    """The IR form of a resolved spec: reachability ∧ invalidate ∧ affinity."""
    predicates: list = [Reachable(), _predicate_of(spec.invalidate)]
    if spec.affinity is not None:
        predicates.append(RunningAll(spec.affinity.functions))
    if spec.anti_affinity is not None:
        predicates.append(RunningNone(spec.anti_affinity.functions))
    return Conjunction(tuple(predicates))


# ---------------------------------------------------------------------------
# Evaluation entry points (shared by interpreter + compiled paths)
# ---------------------------------------------------------------------------


def spec_violated(worker: WorkerState, spec: ConstraintSpec) -> bool:
    """Reference evaluation (un-lowered); equals ``compile_spec(spec)(w)``."""
    return spec_predicate(spec).violated(worker)


def constraint_reason(worker: WorkerState, spec: ConstraintSpec) -> Optional[str]:
    """First violated requirement as a human-readable reason, else None.

    Conjunction member order matches the lowered closure's short-circuit
    order (reachability ▸ invalidate ▸ affinity ▸ anti-affinity), so trace
    output and hot-path validity always agree.
    """
    return spec_predicate(spec).reason(worker)


def split_spec(spec: ConstraintSpec) -> Tuple[InvalidFn, InvalidFn]:
    """Split a resolved spec into ``(static_invalid, dynamic_invalid)``.

    The index layer's contract: ``compile_spec(spec)(w) ==
    static_invalid(w) or dynamic_invalid(w)`` for every worker state.

    *Static* means stable within one ``ClusterState.topology_epoch``:
    reachability and health transitions always bump the epoch (the
    watcher treats them as structural), so an index built per epoch may
    evaluate them once at build time. *Dynamic* is the volatile residue —
    slot counters, load percentages, and the running-function multiset —
    i.e. exactly the fields the admission ledger mutates per decision
    without bumping the epoch. Note the split follows the predicate
    semantics: only ``overload`` consults health; ``capacity_used`` and
    ``max_concurrent_invocations`` have reachability as their sole
    static requirement (paper §3.3).
    """
    invalidate = spec.invalidate
    if isinstance(invalidate, Overload):
        def static_invalid(w) -> bool:
            return (not w.reachable) or (not w.healthy)

        def base_dynamic(w) -> bool:
            return w.inflight >= w.capacity_slots
    elif isinstance(invalidate, CapacityUsed):
        threshold = invalidate.percent

        def static_invalid(w) -> bool:
            return not w.reachable

        def base_dynamic(w) -> bool:
            return w.capacity_used_pct >= threshold
    elif isinstance(invalidate, MaxConcurrentInvocations):
        limit = invalidate.limit

        def static_invalid(w) -> bool:
            return not w.reachable

        def base_dynamic(w) -> bool:
            return (w.inflight + w.queued) >= limit
    else:
        raise TypeError(f"unknown invalidate condition {invalidate!r}")

    if spec.plain:
        return static_invalid, base_dynamic

    aff = spec.affinity.functions if spec.affinity is not None else None
    anti = (
        spec.anti_affinity.functions if spec.anti_affinity is not None else None
    )

    def dynamic_invalid(w) -> bool:
        if base_dynamic(w):
            return True
        rf = w.running_functions
        if aff is not None and any(rf.get(f, 0) <= 0 for f in aff):
            return True
        return anti is not None and any(rf.get(f, 0) > 0 for f in anti)

    return static_invalid, dynamic_invalid


def compile_spec(spec: ConstraintSpec) -> InvalidFn:
    """Lower a resolved spec to one flat pre-resolved closure.

    Plain specs (no affinity clauses) keep the exact single-lambda shape of
    the original compiled fast path; specs with affinity clauses pay one
    extra fused check reading ``running_functions``. Either way the closure
    is resolved once at script-compile time — per-decision cost does not
    grow with the number of constraint kinds in the language.
    """
    base = compile_invalidate(spec.invalidate)
    if spec.plain:
        return base
    aff = spec.affinity.functions if spec.affinity is not None else None
    anti = spec.anti_affinity.functions if spec.anti_affinity is not None else None

    if aff is not None and len(aff) == 1 and anti is None:
        (fa,) = aff

        def invalid(w) -> bool:
            return base(w) or w.running_functions.get(fa, 0) <= 0
        return invalid
    if anti is not None and len(anti) == 1 and aff is None:
        (fn,) = anti

        def invalid(w) -> bool:
            return base(w) or w.running_functions.get(fn, 0) > 0
        return invalid

    def invalid(w) -> bool:
        if base(w):
            return True
        rf = w.running_functions
        if aff is not None and any(rf.get(f, 0) <= 0 for f in aff):
            return True
        return anti is not None and any(rf.get(f, 0) > 0 for f in anti)
    return invalid
