"""The Watcher service (paper §4.2) + live tAPP reload (paper §4.5).

The watcher owns the authoritative cluster state — the mapping from
tAPP-level labels/zones/sets to live workers — and the single global copy
of the current tAPP script. Gateways and controllers keep cached copies;
the watcher bumps a version counter and notifies subscribers on change,
which models the paper's NFS-store + cache-invalidation design without
the NFS indirection.

On a TPU fleet, `poll()` would consume per-host agent heartbeats (HBM
occupancy, queue depth, liveness); in-process the runtime/simulator calls
the mutation methods directly.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.scheduler.state import (
    ClusterState,
    ControllerState,
    HealthState,
    WorkerState,
)
from repro.core.tapp.ast import TappScript
from repro.core.tapp.parser import parse_tapp
from repro.core.tapp.validate import ValidationReport, validate_script

Subscriber = Callable[[str], None]  # event kind: "topology" | "script"

# Worker fields whose transitions invalidate the epoch-cached views.
# zone/sets/capacity_slots change the view *shape*; health, reachability,
# and residency are read live through WorkerState references (the cached
# views stay correct without a rebuild) but are invalidated conservatively,
# so any future policy that filters them out of the view stays safe. These
# are rare transitions; inflight counters, load percentages, and the
# running-function multiset (the affinity signal) are the per-decision
# churn and never bump the epoch, so admissions and completions stay
# cache-hit.
_STRUCTURAL_WORKER_FIELDS = frozenset(
    {
        "zone",
        "sets",
        "capacity_slots",
        "reachable",
        "healthy",
        "health",
        "resident_models",
        "memory_bytes",
    }
)


@dataclasses.dataclass(frozen=True)
class LeaseConfig:
    """Heartbeat-lease thresholds of the failure detector (seconds).

    A worker whose last heartbeat is older than ``suspect_after`` turns
    SUSPECT (deprioritized but placeable); older than ``dead_after`` turns
    DEAD (excluded, in-flight tickets evicted). All lease methods take an
    explicit ``now`` — the detector never reads a wall clock, so seeded
    runs stay deterministic.
    """

    suspect_after: float = 1.5
    dead_after: float = 5.0

    def __post_init__(self) -> None:
        if self.suspect_after <= 0 or self.dead_after <= 0:
            raise ValueError("lease thresholds must be positive")
        if self.dead_after < self.suspect_after:
            raise ValueError(
                f"dead_after ({self.dead_after}) must be >= suspect_after "
                f"({self.suspect_after})"
            )


@dataclasses.dataclass(frozen=True)
class HealthTransition:
    """One failure-detector verdict change, as reported by the watcher."""

    worker: str
    previous: HealthState
    state: HealthState
    at: float
    evicted: int = 0  # in-flight tickets that died with a DEAD transition


class Watcher:
    def __init__(
        self,
        cluster: Optional[ClusterState] = None,
        *,
        lease: Optional[LeaseConfig] = None,
    ) -> None:
        self._lock = threading.RLock()
        # Admission-ledger locks, sharded per zone: the per-decision hot
        # path (record_admission / record_completion) takes only the
        # worker's zone lock, so federated entrypoints never serialize on
        # each other's admission streams. Structural mutations take the
        # global lock first, then the affected zone lock — a strict
        # ordering (global → zone), so the paths cannot deadlock.
        self._zone_locks: Dict[str, threading.Lock] = {}
        self._zone_locks_guard = threading.Lock()
        self._cluster = cluster or ClusterState()
        self._script: Optional[TappScript] = None
        self._script_version = 0
        self._subscribers: List[Subscriber] = []
        self._last_report: Optional[ValidationReport] = None
        self._lease = lease
        # Last-heartbeat timestamps, per worker. Leases are opt-in: a
        # worker enters the detector on its first heartbeat_lease().
        self._leases: Dict[str, float] = {}
        # Warm-pool lifecycle manager (PR 10), attached by an armed
        # platform so worker removal forgets the worker's instances —
        # an instance never outlives its worker. None when unarmed.
        self._lifecycle = None

    # -- subscriptions ---------------------------------------------------------

    def subscribe(self, callback: Subscriber) -> None:
        with self._lock:
            self._subscribers.append(callback)

    def _notify(self, kind: str) -> None:
        for cb in list(self._subscribers):
            cb(kind)

    # -- cluster state ----------------------------------------------------------

    @property
    def cluster(self) -> ClusterState:
        return self._cluster

    def attach_lifecycle(self, manager) -> None:
        """Bind the platform's warm-pool lifecycle manager (PR 10) so
        deregistration and DEAD transitions forget the worker's
        instances in the same breath as the eviction."""
        self._lifecycle = manager

    def _zone_lock(self, zone: str) -> threading.Lock:
        lock = self._zone_locks.get(zone)
        if lock is None:
            with self._zone_locks_guard:
                lock = self._zone_locks.get(zone)
                if lock is None:
                    lock = self._zone_locks[zone] = threading.Lock()
        return lock

    def register_worker(self, worker: WorkerState) -> None:
        """A worker joins (elastic scale-up / node replacement)."""
        with self._lock:
            self._cluster.add_worker(worker)
        self._notify("topology")

    def deregister_worker(self, name: str) -> Optional[WorkerState]:
        """A worker leaves (scale-down, failure eviction).

        Removal goes through the drain path: health and reachability are
        cleared *before* the membership change, all under one lock, so no
        admission can race the removal (``record_admission`` rejects
        unreachable workers), and the single epoch bump of the removal
        invalidates every cached view. Returns the removed state — its
        ``inflight`` count is the number of admission tickets that died
        with the worker, which the platform ledger reconciles as
        evictions (nothing strands).
        """
        with self._lock:
            worker = self._cluster.workers.get(name)
            if worker is not None:
                with self._zone_lock(worker.zone):
                    worker.healthy = False
                    worker.reachable = False
                    self._cluster.remove_worker(name)
            self._leases.pop(name, None)
        if worker is not None and self._lifecycle is not None:
            # Warm instances die with their worker: drop the pools and
            # clear the warmth signal before anyone re-reads it.
            self._lifecycle.forget_worker(name)
        self._notify("topology")
        return worker

    def register_controller(self, controller: ControllerState) -> None:
        with self._lock:
            self._cluster.add_controller(controller)
        self._notify("topology")

    def deregister_controller(self, name: str) -> Optional[ControllerState]:
        """A controller leaves; drained symmetrically to workers (marked
        unavailable before removal, one lock, one epoch bump). Its
        per-worker ``inflight_by`` entitlement entries are retired by the
        normal completion path."""
        with self._lock:
            controller = self._cluster.controllers.get(name)
            if controller is not None:
                controller.healthy = False
                controller.reachable = False
                self._cluster.remove_controller(name)
        self._notify("topology")
        return controller

    def update_worker(self, name: str, **fields) -> None:
        """Apply a heartbeat (load/health/residency update).

        Structural transitions (zone/set/capacity/health/reachability)
        invalidate the epoch-cached topology views; pure load updates
        (inflight counters, capacity percentages) do not.
        """
        with self._lock:
            worker = self._cluster.workers.get(name)
            if worker is None:
                raise KeyError(f"unknown worker {name!r}")
            structural = False
            volatile = False
            zone_changed = False
            updates = []
            for key, value in fields.items():
                if not hasattr(worker, key):
                    raise AttributeError(f"WorkerState has no field {key!r}")
                if key in ("sets", "resident_models"):
                    value = frozenset(value)
                elif key == "health" and not isinstance(value, HealthState):
                    value = HealthState(value)
                if key in _STRUCTURAL_WORKER_FIELDS:
                    if getattr(worker, key) != value:
                        structural = True
                        if key == "zone":
                            zone_changed = True
                else:
                    volatile = True
                updates.append((key, value))
            zone = worker.zone
            if zone_changed:
                # A zone move must exclude the hot paths of BOTH zones:
                # the instant the ``zone`` setattr lands, a concurrent
                # record_admission re-reading worker.zone takes the NEW
                # zone's lock, so holding only the old lock would let
                # counter writes interleave with the structural update.
                # Both locks are taken in sorted order (and only ever
                # under the global lock, which serializes structural
                # mutations), so lock ordering stays deterministic.
                new_zone = next(v for k, v in updates if k == "zone")
                first, second = sorted((zone, new_zone))
                with self._zone_lock(first), self._zone_lock(second):
                    for key, value in updates:
                        setattr(worker, key, value)
                    self._cluster.version += 1
            else:
                with self._zone_lock(zone):
                    for key, value in updates:
                        setattr(worker, key, value)
                    self._cluster.version += 1
                    if not structural and volatile:
                        # Load-only update: candidate indexes refresh
                        # this worker's availability bits incrementally
                        # instead of rebuilding.
                        self._cluster.note_worker_load(name, zone)
            if structural:
                if zone_changed:
                    # A zone move touches two zones' views; invalidate
                    # globally and rebuild the per-zone member map.
                    self._cluster.invalidate_zone_members()
                    self._cluster.bump_topology_epoch()
                else:
                    self._cluster.bump_topology_epoch(zone)

    def update_controller(self, name: str, **fields) -> None:
        """Apply a controller transition (health / reachability).

        Controller availability is read live by the engine's resolution
        paths, but the epoch is bumped conservatively (like worker
        health) so any future view that filters on it stays safe.
        """
        with self._lock:
            controller = self._cluster.controllers.get(name)
            if controller is None:
                raise KeyError(f"unknown controller {name!r}")
            for key, value in fields.items():
                if not hasattr(controller, key):
                    raise AttributeError(
                        f"ControllerState has no field {key!r}"
                    )
                setattr(controller, key, value)
            self._cluster.version += 1
            self._cluster.bump_topology_epoch()
        self._notify("topology")

    def mark_unreachable(self, name: str) -> None:
        self.update_worker(name, reachable=False)
        self._notify("topology")

    def mark_unhealthy(self, name: str) -> None:
        self.update_worker(name, healthy=False)
        self._notify("topology")

    def mark_drained(self, name: str) -> None:
        """Clear health AND reachability in one transition (graceful
        drain): unreachability is the preliminary invalidate condition of
        every policy, so no script admits onto the worker, while
        :meth:`record_completion` still retires its running tickets."""
        self.update_worker(name, healthy=False, reachable=False)
        self._notify("topology")

    def mark_restored(self, name: str) -> None:
        """Clear health + reachability flags (recovery / undrain) — the
        symmetric notification to :meth:`mark_unhealthy` /
        :meth:`mark_unreachable`. Also resets the failure detector's
        verdict: a restored worker is HEALTHY again (its eviction history
        stays recorded through the generation counter)."""
        self.update_worker(
            name, healthy=True, reachable=True, health=HealthState.HEALTHY
        )
        self._notify("topology")

    # -- failure detection (heartbeat leases, PR 6) ------------------------------

    @property
    def lease_config(self) -> Optional[LeaseConfig]:
        return self._lease

    def configure_lease(self, lease: LeaseConfig) -> None:
        """Install (or replace) the failure detector's lease thresholds."""
        with self._lock:
            self._lease = lease

    def heartbeat_lease(
        self, name: str, now: float, **fields
    ) -> Optional[HealthTransition]:
        """Renew a worker's heartbeat lease at time ``now``.

        Enters the worker into the failure detector on first call. A
        heartbeat from a SUSPECT or DEAD worker is the recovery signal:
        the verdict returns to HEALTHY, health + reachability flags are
        restored, and the transition is reported (None: no verdict
        change). Extra keyword fields are applied as a regular
        :meth:`update_worker` heartbeat in the same lock hold. Unknown
        workers raise ``KeyError`` — a drained/deregistered worker's lease
        is gone and cannot resurrect its state.
        """
        transition: Optional[HealthTransition] = None
        with self._lock:
            worker = self._cluster.workers.get(name)
            if worker is None:
                raise KeyError(f"unknown worker {name!r}")
            self._leases[name] = float(now)
            if worker.health is not HealthState.HEALTHY:
                previous = worker.health
                self.update_worker(
                    name, healthy=True, reachable=True,
                    health=HealthState.HEALTHY,
                )
                transition = HealthTransition(
                    worker=name, previous=previous,
                    state=HealthState.HEALTHY, at=float(now),
                )
            if fields:
                self.update_worker(name, **fields)
        if transition is not None:
            self._notify("topology")
        return transition

    def check_leases(self, now: float) -> List[HealthTransition]:
        """Advance the failure detector to time ``now``.

        Expired leases transition HEALTHY→SUSPECT→DEAD per the
        :class:`LeaseConfig` thresholds; each DEAD transition evicts the
        worker's in-flight tickets (see :meth:`mark_dead`) and reports the
        evicted count so the platform ledger can reconcile. Returns the
        transitions in worker registration order.
        """
        lease = self._lease
        if lease is None:
            raise ValueError(
                "watcher has no LeaseConfig; pass lease= at construction "
                "or call configure_lease()"
            )
        transitions: List[HealthTransition] = []
        structural = False
        with self._lock:
            for name in list(self._leases):
                worker = self._cluster.workers.get(name)
                if worker is None:
                    del self._leases[name]
                    continue
                age = float(now) - self._leases[name]
                if age >= lease.dead_after:
                    if worker.health is not HealthState.DEAD:
                        previous = worker.health
                        evicted = self._kill_locked(worker)
                        structural = True
                        transitions.append(
                            HealthTransition(
                                worker=name, previous=previous,
                                state=HealthState.DEAD, at=float(now),
                                evicted=evicted,
                            )
                        )
                elif age >= lease.suspect_after:
                    if worker.health is HealthState.HEALTHY:
                        worker.health = HealthState.SUSPECT
                        structural = True
                        transitions.append(
                            HealthTransition(
                                worker=name, previous=HealthState.HEALTHY,
                                state=HealthState.SUSPECT, at=float(now),
                            )
                        )
            if structural:
                self._cluster.version += 1
                self._cluster.bump_topology_epoch()
        if transitions:
            self._notify("topology")
        return transitions

    def _kill_locked(self, worker: WorkerState) -> int:
        """DEAD transition under the lock: evict in-flight tickets, bump
        the incarnation, clear health + reachability. Returns the number
        of tickets that died with the worker (the caller reconciles them
        as ledger evictions, reusing the deregistration-drain shape).
        Takes the worker's zone lock so the counter wipe cannot interleave
        with a concurrent admission/completion on the hot path."""
        with self._zone_lock(worker.zone):
            evicted = worker.inflight
            worker.inflight = 0
            worker.inflight_by.clear()
            worker.running_functions.clear()
            worker.queued = 0
            worker.capacity_used_pct = 100.0
            worker.generation += 1
            worker.health = HealthState.DEAD
            worker.healthy = False
            worker.reachable = False
        if self._lifecycle is not None:
            # A crash kills the worker's instances too (the restarted
            # incarnation boots with empty pools).
            self._lifecycle.forget_worker(worker.name)
        return evicted

    def mark_dead(self, name: str) -> int:
        """Declare a worker DEAD immediately (crash signal / injected
        fault) — the same transition :meth:`check_leases` performs on a
        fully-expired lease. Idempotent (0 evictions the second time);
        unknown workers raise ``KeyError``. Returns the evicted in-flight
        ticket count for ledger reconciliation."""
        with self._lock:
            worker = self._cluster.workers.get(name)
            if worker is None:
                raise KeyError(f"unknown worker {name!r}")
            if worker.health is HealthState.DEAD:
                return 0
            evicted = self._kill_locked(worker)
            self._cluster.version += 1
            self._cluster.bump_topology_epoch(worker.zone)
        self._notify("topology")
        return evicted

    def mark_suspect(self, name: str) -> None:
        """Flag a worker SUSPECT (flappy-heartbeat signal): deprioritized
        in candidate ordering but still placeable. No-op unless currently
        HEALTHY; unknown workers raise ``KeyError``."""
        with self._lock:
            worker = self._cluster.workers.get(name)
            if worker is None:
                raise KeyError(f"unknown worker {name!r}")
            if worker.health is not HealthState.HEALTHY:
                return
            worker.health = HealthState.SUSPECT
            self._cluster.version += 1
            self._cluster.bump_topology_epoch(worker.zone)
        self._notify("topology")

    # -- retry exclusion masks ---------------------------------------------------

    def mask_unreachable(self, names: Iterable[str]) -> Tuple[str, ...]:
        """Temporarily mark workers unreachable (a retry's already-tried
        exclusion set). Returns exactly the workers that were reachable
        and got masked — pass it to :meth:`unmask` to restore, so workers
        unreachable for *other* reasons are never resurrected by the
        restore. Retries are the failure path, so the epoch bump's index
        rebuild cost is acceptable."""
        masked: List[str] = []
        zones: set = set()
        with self._lock:
            for name in names:
                worker = self._cluster.workers.get(name)
                if worker is not None and worker.reachable:
                    worker.reachable = False
                    masked.append(name)
                    zones.add(worker.zone)
            if masked:
                self._cluster.version += 1
                self._cluster.bump_topology_epoch(
                    zones.pop() if len(zones) == 1 else None
                )
        return tuple(masked)

    def unmask(self, names: Sequence[str]) -> None:
        """Restore reachability for workers previously masked by
        :meth:`mask_unreachable` (no subscriber notification — the mask
        is a transient routing-internal state, not a topology event)."""
        restored = False
        zones: set = set()
        with self._lock:
            for name in names:
                worker = self._cluster.workers.get(name)
                if worker is not None and not worker.reachable:
                    worker.reachable = True
                    restored = True
                    zones.add(worker.zone)
            if restored:
                self._cluster.version += 1
                self._cluster.bump_topology_epoch(
                    zones.pop() if len(zones) == 1 else None
                )

    # -- admission ledger fast path ---------------------------------------------
    #
    # Admissions and completions touch only volatile load fields (inflight
    # counters, the per-controller split, the running-function multiset,
    # capacity percentage) — never the structural fields that invalidate
    # epoch-cached views. These two methods are the per-decision hot path
    # the controller runtime uses: one lock hold, in-place counter updates,
    # no structural scan. Each records the worker on the cluster's
    # volatile-load log (``note_worker_load``), which is how the per-epoch
    # candidate indexes learn — in O(1) — that exactly this worker's
    # availability bits need refreshing. Heartbeats and topology
    # transitions still go through :meth:`update_worker`.

    def record_admission(
        self, name: str, controller: str, function: str = ""
    ) -> WorkerState:
        """Record one admitted invocation (raises ``KeyError`` for an
        unknown worker, ``ValueError`` for an unreachable one — the
        preliminary condition of every policy, paper §3.3). Returns the
        live worker the ticket was taken on: completion paths pass it
        back as ``expected`` so a ticket can never retire against a
        *different* worker that later re-used the name.

        Locking: takes only the worker's *zone* lock — zone-local writes —
        so concurrent entrypoints of different zones admit in parallel
        instead of serializing on one global ledger lock. The zone is
        re-read after acquiring the lock: a concurrent zone move
        (update_worker holds both zones' locks for the whole update) may
        have re-homed the worker between the unlocked read and the
        acquire, in which case the admission retries on the new zone's
        lock instead of writing counters under the wrong one."""
        cluster = self._cluster
        worker = cluster.workers[name]
        while True:
            zone = worker.zone
            lock = self._zone_locks.get(zone)
            if lock is None:
                lock = self._zone_lock(zone)
            lock.acquire()
            if worker.zone == zone:
                break
            lock.release()
        try:
            if not worker.reachable:
                raise ValueError(f"worker {name!r} unreachable")
            inflight = worker.inflight + 1
            worker.inflight = inflight
            by = worker.inflight_by
            by[controller] = by.get(controller, 0) + 1
            if function:
                running = worker.running_functions
                running[function] = running.get(function, 0) + 1
            slots = worker.capacity_slots
            if 0 < inflight < slots:
                worker.capacity_used_pct = 100.0 * inflight / slots
            else:
                worker.capacity_used_pct = 100.0
            cluster.version += 1
            cluster.note_worker_load(name, zone)
            return worker
        finally:
            lock.release()

    def record_completion(
        self,
        name: str,
        controller: str,
        function: str = "",
        *,
        slow: bool = False,
        expected: Optional[WorkerState] = None,
        generation: Optional[int] = None,
    ) -> bool:
        """Retire one admission ticket; returns whether a live ticket was
        actually released (``False`` when the worker was evicted while the
        work ran — its tickets were already reconciled at removal).
        ``expected`` is the worker the admission was recorded on: if a
        *different* worker has since re-used the name, the ticket is NOT
        released against it (it died with the original and was reconciled
        at deregistration), keeping the replacement's counters honest.
        ``generation`` is the worker's incarnation at admission: if the
        worker has since crashed (a DEAD transition evicted its tickets
        and bumped the counter), the ticket is likewise declined even if
        the same instance recovered.
        """
        worker = self._cluster.workers.get(name)
        if worker is None:
            return False  # worker evicted while running; ticket gone
        # Same zone re-validation as record_admission: a concurrent zone
        # move may re-home the worker between the unlocked zone read and
        # the lock acquire.
        while True:
            zone = worker.zone
            lock = self._zone_locks.get(zone)
            if lock is None:
                lock = self._zone_lock(zone)
            lock.acquire()
            if worker.zone == zone:
                break
            lock.release()
        try:
            if expected is not None and worker is not expected:
                return False  # name re-used by a different worker
            if generation is not None and worker.generation != generation:
                return False  # ticket evicted at a crash; already reconciled
            inflight = worker.inflight - 1
            if inflight < 0:
                inflight = 0
            worker.inflight = inflight
            by = worker.inflight_by
            own = by.get(controller, 1) - 1
            by[controller] = own if own > 0 else 0
            if function:
                running = worker.running_functions
                remaining = running.get(function, 1) - 1
                if remaining > 0:
                    running[function] = remaining
                else:
                    running.pop(function, None)
            slots = worker.capacity_slots
            if slow:
                # Straggler signal: report the worker as fully loaded so
                # capacity_used-based policies route around it until the
                # next healthy heartbeat clears the flag.
                worker.capacity_used_pct = 100.0
            else:
                worker.capacity_used_pct = (
                    100.0 if slots <= 0
                    else min(100.0, 100.0 * inflight / slots)
                )
            self._cluster.version += 1
            self._cluster.note_worker_load(name, zone)
            return True
        finally:
            lock.release()

    # -- script store (live reload, §4.5) ---------------------------------------

    @property
    def script(self) -> Optional[TappScript]:
        return self._script

    @property
    def script_version(self) -> int:
        return self._script_version

    @property
    def last_validation(self) -> Optional[ValidationReport]:
        return self._last_report

    def load_script(self, yaml_text: str, *, strict: bool = True) -> TappScript:
        """Parse + validate + atomically publish a new tAPP script.

        With ``strict`` the update is rejected on validation *errors*
        (the live system keeps the previous script — no partial state);
        topology warnings never block, since set membership is dynamic.
        """
        return self.publish_script(parse_tapp(yaml_text), strict=strict)

    def publish_script(
        self, script: TappScript, *, strict: bool = True, gate=None
    ) -> TappScript:
        """Validate + atomically publish an already-parsed tAPP script.

        The platform's policy lifecycle (apply / dry-run / rollback) builds
        on this: validation, the caller's acceptance check, and the
        version-bumped swap all happen under one lock, so readers either
        see the previous script or the complete new one — never partial
        state, and never a script gated against a stale topology.

        ``gate`` is an optional callable invoked with the
        :class:`~repro.core.tapp.validate.ValidationReport` while the lock
        is held (the lock is reentrant, so the callable may read this
        watcher's cluster); raising from it aborts the publish with nothing
        swapped. When ``gate`` is given it replaces the default ``strict``
        error check.
        """
        with self._lock:
            report = validate_script(
                script,
                known_controllers=self._cluster.controller_names(),
                known_worker_labels=self._cluster.worker_names(),
                known_set_labels=self._cluster.set_labels(),
            )
            self._last_report = report
            if gate is not None:
                gate(report)
            elif strict:
                report.raise_on_error()
            self._script_version += 1
            self._script = TappScript(
                tags=script.tags,
                source=script.source,
                version=self._script_version,
            )
        self._notify("script")
        return self._script

    def clear_script(self) -> None:
        """Remove the script → platforms fall back to vanilla (paper §4.3)."""
        with self._lock:
            self._script = None
            self._script_version += 1
        self._notify("script")

    # -- snapshotting --------------------------------------------------------------

    def snapshot_labels(self) -> Dict[str, Dict]:
        """The label→node mapping the paper's watcher stores on NFS."""
        with self._lock:
            return {
                "workers": {
                    w.name: {"zone": w.zone, "sets": sorted(w.sets)}
                    for w in self._cluster.workers.values()
                },
                "controllers": {
                    c.name: {"zone": c.zone}
                    for c in self._cluster.controllers.values()
                },
                "version": self._cluster.version,
            }
