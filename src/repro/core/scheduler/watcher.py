"""The Watcher service (paper §4.2) + live tAPP reload (paper §4.5).

The watcher owns the authoritative cluster state — the mapping from
tAPP-level labels/zones/sets to live workers — and the single global copy
of the current tAPP script. Gateways and controllers keep cached copies;
the watcher bumps a version counter and notifies subscribers on change,
which models the paper's NFS-store + cache-invalidation design without
the NFS indirection.

On a TPU fleet, `poll()` would consume per-host agent heartbeats (HBM
occupancy, queue depth, liveness); in-process the runtime/simulator calls
the mutation methods directly.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Iterable, List, Optional

from repro.core.scheduler.state import ClusterState, ControllerState, WorkerState
from repro.core.tapp.ast import TappScript
from repro.core.tapp.parser import parse_tapp
from repro.core.tapp.validate import ValidationReport, validate_script

Subscriber = Callable[[str], None]  # event kind: "topology" | "script"

# Worker fields whose transitions invalidate the epoch-cached views.
# zone/sets/capacity_slots change the view *shape*; health, reachability,
# and residency are read live through WorkerState references (the cached
# views stay correct without a rebuild) but are invalidated conservatively,
# so any future policy that filters them out of the view stays safe. These
# are rare transitions; inflight counters, load percentages, and the
# running-function multiset (the affinity signal) are the per-decision
# churn and never bump the epoch, so admissions and completions stay
# cache-hit.
_STRUCTURAL_WORKER_FIELDS = frozenset(
    {
        "zone",
        "sets",
        "capacity_slots",
        "reachable",
        "healthy",
        "resident_models",
        "memory_bytes",
    }
)


class Watcher:
    def __init__(self, cluster: Optional[ClusterState] = None) -> None:
        self._lock = threading.RLock()
        self._cluster = cluster or ClusterState()
        self._script: Optional[TappScript] = None
        self._script_version = 0
        self._subscribers: List[Subscriber] = []
        self._last_report: Optional[ValidationReport] = None

    # -- subscriptions ---------------------------------------------------------

    def subscribe(self, callback: Subscriber) -> None:
        with self._lock:
            self._subscribers.append(callback)

    def _notify(self, kind: str) -> None:
        for cb in list(self._subscribers):
            cb(kind)

    # -- cluster state ----------------------------------------------------------

    @property
    def cluster(self) -> ClusterState:
        return self._cluster

    def register_worker(self, worker: WorkerState) -> None:
        """A worker joins (elastic scale-up / node replacement)."""
        with self._lock:
            self._cluster.add_worker(worker)
        self._notify("topology")

    def deregister_worker(self, name: str) -> Optional[WorkerState]:
        """A worker leaves (scale-down, failure eviction).

        Removal goes through the drain path: health and reachability are
        cleared *before* the membership change, all under one lock, so no
        admission can race the removal (``record_admission`` rejects
        unreachable workers), and the single epoch bump of the removal
        invalidates every cached view. Returns the removed state — its
        ``inflight`` count is the number of admission tickets that died
        with the worker, which the platform ledger reconciles as
        evictions (nothing strands).
        """
        with self._lock:
            worker = self._cluster.workers.get(name)
            if worker is not None:
                worker.healthy = False
                worker.reachable = False
                self._cluster.remove_worker(name)
        self._notify("topology")
        return worker

    def register_controller(self, controller: ControllerState) -> None:
        with self._lock:
            self._cluster.add_controller(controller)
        self._notify("topology")

    def deregister_controller(self, name: str) -> Optional[ControllerState]:
        """A controller leaves; drained symmetrically to workers (marked
        unavailable before removal, one lock, one epoch bump). Its
        per-worker ``inflight_by`` entitlement entries are retired by the
        normal completion path."""
        with self._lock:
            controller = self._cluster.controllers.get(name)
            if controller is not None:
                controller.healthy = False
                controller.reachable = False
                self._cluster.remove_controller(name)
        self._notify("topology")
        return controller

    def update_worker(self, name: str, **fields) -> None:
        """Apply a heartbeat (load/health/residency update).

        Structural transitions (zone/set/capacity/health/reachability)
        invalidate the epoch-cached topology views; pure load updates
        (inflight counters, capacity percentages) do not.
        """
        with self._lock:
            worker = self._cluster.workers.get(name)
            if worker is None:
                raise KeyError(f"unknown worker {name!r}")
            structural = False
            volatile = False
            for key, value in fields.items():
                if not hasattr(worker, key):
                    raise AttributeError(f"WorkerState has no field {key!r}")
                if key in ("sets", "resident_models"):
                    value = frozenset(value)
                if key in _STRUCTURAL_WORKER_FIELDS:
                    if getattr(worker, key) != value:
                        structural = True
                else:
                    volatile = True
                setattr(worker, key, value)
            self._cluster.version += 1
            if structural:
                self._cluster.bump_topology_epoch()
            elif volatile:
                # Load-only update: candidate indexes refresh this worker's
                # availability bits incrementally instead of rebuilding.
                self._cluster.note_worker_load(name)

    def update_controller(self, name: str, **fields) -> None:
        """Apply a controller transition (health / reachability).

        Controller availability is read live by the engine's resolution
        paths, but the epoch is bumped conservatively (like worker
        health) so any future view that filters on it stays safe.
        """
        with self._lock:
            controller = self._cluster.controllers.get(name)
            if controller is None:
                raise KeyError(f"unknown controller {name!r}")
            for key, value in fields.items():
                if not hasattr(controller, key):
                    raise AttributeError(
                        f"ControllerState has no field {key!r}"
                    )
                setattr(controller, key, value)
            self._cluster.version += 1
            self._cluster.bump_topology_epoch()
        self._notify("topology")

    def mark_unreachable(self, name: str) -> None:
        self.update_worker(name, reachable=False)
        self._notify("topology")

    def mark_unhealthy(self, name: str) -> None:
        self.update_worker(name, healthy=False)
        self._notify("topology")

    def mark_drained(self, name: str) -> None:
        """Clear health AND reachability in one transition (graceful
        drain): unreachability is the preliminary invalidate condition of
        every policy, so no script admits onto the worker, while
        :meth:`record_completion` still retires its running tickets."""
        self.update_worker(name, healthy=False, reachable=False)
        self._notify("topology")

    def mark_restored(self, name: str) -> None:
        """Clear health + reachability flags (recovery / undrain) — the
        symmetric notification to :meth:`mark_unhealthy` /
        :meth:`mark_unreachable`."""
        self.update_worker(name, healthy=True, reachable=True)
        self._notify("topology")

    # -- admission ledger fast path ---------------------------------------------
    #
    # Admissions and completions touch only volatile load fields (inflight
    # counters, the per-controller split, the running-function multiset,
    # capacity percentage) — never the structural fields that invalidate
    # epoch-cached views. These two methods are the per-decision hot path
    # the controller runtime uses: one lock hold, in-place counter updates,
    # no structural scan. Each records the worker on the cluster's
    # volatile-load log (``note_worker_load``), which is how the per-epoch
    # candidate indexes learn — in O(1) — that exactly this worker's
    # availability bits need refreshing. Heartbeats and topology
    # transitions still go through :meth:`update_worker`.

    def record_admission(
        self, name: str, controller: str, function: str = ""
    ) -> WorkerState:
        """Record one admitted invocation (raises ``KeyError`` for an
        unknown worker, ``ValueError`` for an unreachable one — the
        preliminary condition of every policy, paper §3.3). Returns the
        live worker the ticket was taken on: completion paths pass it
        back as ``expected`` so a ticket can never retire against a
        *different* worker that later re-used the name."""
        cluster = self._cluster
        with self._lock:
            worker = cluster.workers[name]
            if not worker.reachable:
                raise ValueError(f"worker {name!r} unreachable")
            inflight = worker.inflight + 1
            worker.inflight = inflight
            by = worker.inflight_by
            by[controller] = by.get(controller, 0) + 1
            if function:
                running = worker.running_functions
                running[function] = running.get(function, 0) + 1
            slots = worker.capacity_slots
            if 0 < inflight < slots:
                worker.capacity_used_pct = 100.0 * inflight / slots
            else:
                worker.capacity_used_pct = 100.0
            cluster.version += 1
            cluster.note_worker_load(name)
            return worker

    def record_completion(
        self,
        name: str,
        controller: str,
        function: str = "",
        *,
        slow: bool = False,
        expected: Optional[WorkerState] = None,
    ) -> bool:
        """Retire one admission ticket; returns whether a live ticket was
        actually released (``False`` when the worker was evicted while the
        work ran — its tickets were already reconciled at removal).
        ``expected`` is the worker the admission was recorded on: if a
        *different* worker has since re-used the name, the ticket is NOT
        released against it (it died with the original and was reconciled
        at deregistration), keeping the replacement's counters honest.
        """
        with self._lock:
            worker = self._cluster.workers.get(name)
            if worker is None:
                return False  # worker evicted while running; ticket gone
            if expected is not None and worker is not expected:
                return False  # name re-used by a different worker
            worker.inflight = max(0, worker.inflight - 1)
            by = worker.inflight_by
            by[controller] = max(0, by.get(controller, 1) - 1)
            if function:
                running = worker.running_functions
                remaining = running.get(function, 1) - 1
                if remaining > 0:
                    running[function] = remaining
                else:
                    running.pop(function, None)
            slots = worker.capacity_slots
            if slow:
                # Straggler signal: report the worker as fully loaded so
                # capacity_used-based policies route around it until the
                # next healthy heartbeat clears the flag.
                worker.capacity_used_pct = 100.0
            else:
                worker.capacity_used_pct = (
                    100.0 if slots <= 0
                    else min(100.0, 100.0 * worker.inflight / slots)
                )
            self._cluster.version += 1
            self._cluster.note_worker_load(name)
        return True

    # -- script store (live reload, §4.5) ---------------------------------------

    @property
    def script(self) -> Optional[TappScript]:
        return self._script

    @property
    def script_version(self) -> int:
        return self._script_version

    @property
    def last_validation(self) -> Optional[ValidationReport]:
        return self._last_report

    def load_script(self, yaml_text: str, *, strict: bool = True) -> TappScript:
        """Parse + validate + atomically publish a new tAPP script.

        With ``strict`` the update is rejected on validation *errors*
        (the live system keeps the previous script — no partial state);
        topology warnings never block, since set membership is dynamic.
        """
        return self.publish_script(parse_tapp(yaml_text), strict=strict)

    def publish_script(
        self, script: TappScript, *, strict: bool = True, gate=None
    ) -> TappScript:
        """Validate + atomically publish an already-parsed tAPP script.

        The platform's policy lifecycle (apply / dry-run / rollback) builds
        on this: validation, the caller's acceptance check, and the
        version-bumped swap all happen under one lock, so readers either
        see the previous script or the complete new one — never partial
        state, and never a script gated against a stale topology.

        ``gate`` is an optional callable invoked with the
        :class:`~repro.core.tapp.validate.ValidationReport` while the lock
        is held (the lock is reentrant, so the callable may read this
        watcher's cluster); raising from it aborts the publish with nothing
        swapped. When ``gate`` is given it replaces the default ``strict``
        error check.
        """
        with self._lock:
            report = validate_script(
                script,
                known_controllers=self._cluster.controller_names(),
                known_worker_labels=self._cluster.worker_names(),
                known_set_labels=self._cluster.set_labels(),
            )
            self._last_report = report
            if gate is not None:
                gate(report)
            elif strict:
                report.raise_on_error()
            self._script_version += 1
            self._script = TappScript(
                tags=script.tags,
                source=script.source,
                version=self._script_version,
            )
        self._notify("script")
        return self._script

    def clear_script(self) -> None:
        """Remove the script → platforms fall back to vanilla (paper §4.3)."""
        with self._lock:
            self._script = None
            self._script_version += 1
        self._notify("script")

    # -- snapshotting --------------------------------------------------------------

    def snapshot_labels(self) -> Dict[str, Dict]:
        """The label→node mapping the paper's watcher stores on NFS."""
        with self._lock:
            return {
                "workers": {
                    w.name: {"zone": w.zone, "sets": sorted(w.sets)}
                    for w in self._cluster.workers.values()
                },
                "controllers": {
                    c.name: {"zone": c.zone}
                    for c in self._cluster.controllers.values()
                },
                "version": self._cluster.version,
            }
